//! Property-based tests over the paper's memory-correctness invariants
//! (§IV-C) and the simulator substrate, using the in-tree harness
//! (`axle::util::prop`). Replay a failure with `AXLE_PROP_SEED=<hex>`.

use axle::config::{Protocol, SchedPolicy, SimConfig};
use axle::ring::{ProducerView, Ring};
use axle::sim::{BusyTracker, EventQueue, PuPool};
use axle::util::prop::run_prop;
use axle::util::rng::Pcg32;
use axle::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};
use axle::protocol;

// ------------------------------------------------------------------
// Ring buffer invariants (gap-aware OoO, wraparound, monotonicity).
// ------------------------------------------------------------------

#[test]
fn prop_ring_invariants_under_random_ops() {
    run_prop("ring_invariants", 300, |rng| {
        let cap = rng.range(1, 64) as usize;
        let mut ring = Ring::new(cap);
        let mut outstanding: Vec<u64> = Vec::new();
        let mut last_head = 0u64;
        for _ in 0..rng.range(10, 400) {
            if rng.next_f64() < 0.5 && ring.free() > 0 {
                let n = rng.range(1, ring.free());
                let first = ring.produce(n);
                outstanding.extend(first..first + n);
            } else if !outstanding.is_empty() {
                // Consume a random outstanding slot (OoO).
                let i = rng.below(outstanding.len() as u64) as usize;
                let id = outstanding.swap_remove(i);
                let head = ring.consume(id);
                // Head is monotone.
                assert!(head >= last_head);
                last_head = head;
                // Gap-aware: head never passes an unconsumed slot.
                if let Some(&min_out) = outstanding.iter().min() {
                    assert!(head <= min_out);
                }
            }
            ring.check_invariants();
            assert!(ring.occupancy() <= cap as u64);
        }
    });
}

#[test]
fn prop_producer_view_never_allows_overwrite() {
    // The conservative stale head can *stall* the producer but never let
    // tail overtake the true consumption frontier by more than capacity.
    run_prop("producer_view_safety", 300, |rng| {
        let cap = rng.range(1, 32) as usize;
        let mut host = Ring::new(cap);
        let mut pv = ProducerView::new(cap);
        let mut in_flight: Vec<(u64, u64)> = Vec::new(); // (first, n) sent, unarrived
        let mut unconsumed: Vec<u64> = Vec::new();
        for _ in 0..rng.range(10, 300) {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, cap as u64);
                    if let Some(first) = pv.try_claim(n) {
                        in_flight.push((first, n));
                    }
                }
                1 => {
                    if !in_flight.is_empty() {
                        // Arrival (FIFO, like the wire).
                        let (first, n) = in_flight.remove(0);
                        // Must never overflow the host ring: the claim was
                        // gated by the (possibly stale) head view.
                        assert!(host.occupancy() + n <= cap as u64, "overwrite!");
                        let f2 = host.produce(n);
                        assert_eq!(f2, first);
                        unconsumed.extend(first..first + n);
                    }
                }
                2 => {
                    if !unconsumed.is_empty() {
                        let i = rng.below(unconsumed.len() as u64) as usize;
                        let id = unconsumed.swap_remove(i);
                        host.consume(id);
                    }
                }
                _ => {
                    // Flow-control message (possibly stale/reordered).
                    let head = if rng.next_f64() < 0.3 {
                        rng.range(0, host.head())
                    } else {
                        host.head()
                    };
                    pv.update_head(head.min(host.head()));
                }
            }
        }
    });
}

// ------------------------------------------------------------------
// Event queue and pool.
// ------------------------------------------------------------------

#[test]
fn prop_event_queue_total_order() {
    run_prop("event_queue_order", 200, |rng| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = rng.range(1, 500);
        for i in 0..n {
            q.push_at(rng.below(1000), i);
        }
        let mut last_t = 0;
        let mut seen = 0;
        let mut at_time: Vec<(u64, u64)> = Vec::new();
        while let Some((t, ev)) = q.pop() {
            assert!(t >= last_t, "time went backwards");
            at_time.push((t, ev));
            last_t = t;
            seen += 1;
        }
        assert_eq!(seen, n);
        // FIFO within equal timestamps: insertion ids ascending.
        for w in at_time.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "FIFO tie-break violated");
            }
        }
    });
}

#[test]
fn prop_pool_conservation_and_capacity() {
    run_prop("pool_conservation", 200, |rng| {
        let n_pus = rng.range(1, 16) as usize;
        let mut pool = PuPool::new(n_pus);
        let mut total: u64 = 0;
        let mut makespan: u64 = 0;
        let tasks = rng.range(1, 200);
        let mut ready = 0u64;
        for _ in 0..tasks {
            ready += rng.below(50);
            let dur = rng.range(1, 1000);
            let (start, end) = pool.dispatch(ready, dur);
            assert!(start >= ready);
            assert_eq!(end - start, dur);
            total += dur;
            makespan = makespan.max(end);
        }
        // Work conservation: makespan bounds.
        assert!(makespan >= total / n_pus as u64);
        assert!(pool.busy().total() == total);
        assert!(pool.busy().union() <= makespan);
    });
}

#[test]
fn prop_busy_tracker_union_le_total() {
    run_prop("busy_union", 200, |rng| {
        let mut b = BusyTracker::new();
        let mut start = 0u64;
        for _ in 0..rng.range(1, 100) {
            start += rng.below(100);
            let end = start + rng.below(100);
            b.record(start, end);
        }
        assert!(b.union() <= b.total());
        assert!(b.union() <= b.last_end());
    });
}

// ------------------------------------------------------------------
// Whole-protocol properties over random workloads.
// ------------------------------------------------------------------

fn random_workload(rng: &mut Pcg32) -> WorkloadSpec {
    let iters = rng.range(1, 4) as usize;
    let spec = WorkloadSpec {
        name: "prop".into(),
        annot: 'x',
        domain: "prop",
        iters: (0..iters)
            .map(|_| {
                let n = rng.range(1, 40) as usize;
                let ccm_tasks: Vec<CcmTask> = (0..n)
                    .map(|_| CcmTask {
                        dur: rng.range(1_000, 10_000_000),
                        result_bytes: rng.range(4, 4096),
                    })
                    .collect();
                // Random dependency structure: either 1:1 or gathered.
                let gathered = rng.next_f64() < 0.3;
                let host_tasks: Vec<HostTask> = if gathered {
                    let groups = rng.range(1, (n as u64).min(8)) as usize;
                    (0..groups)
                        .map(|g| HostTask {
                            dur: rng.range(1_000, 5_000_000),
                            deps: (0..n as u32).filter(|t| *t as usize % groups == g).collect(),
                        })
                        .collect()
                } else {
                    (0..n)
                        .map(|i| HostTask {
                            dur: rng.range(1_000, 5_000_000),
                            deps: vec![i as u32],
                        })
                        .collect()
                };
                IterSpec { ccm_tasks, host_tasks, host_serial: rng.next_f64() < 0.2 }
            })
            .collect(),
    };
    spec.validate().expect("generated spec valid");
    spec
}

#[test]
fn prop_all_protocols_complete_random_workloads() {
    run_prop("protocols_complete", 60, |rng| {
        let w = random_workload(rng);
        let mut cfg = SimConfig::m2ndp();
        cfg.seed = rng.next_u64();
        cfg.sched = if rng.next_f64() < 0.5 { SchedPolicy::RoundRobin } else { SchedPolicy::Fifo };
        cfg.axle.ooo_streaming = rng.next_f64() < 0.8;
        for p in Protocol::ALL {
            let m = protocol::run(p, &w, &cfg);
            assert!(!m.deadlock, "{} deadlocked (ample capacity)", p.label());
            assert!(m.total > 0);
            // Physicality: component busy-unions never exceed the total.
            assert!(m.ccm_busy <= m.total);
            assert!(m.host_busy <= m.total);
            assert!(m.dm_busy <= m.total + cfg.cxl_io_rtt + cfg.cxl_mem_rtt);
            // The pipeline can't beat its longest component.
            assert!(m.total >= m.ccm_busy.max(m.host_busy));
        }
    });
}

#[test]
fn prop_axle_not_slower_than_bs_beyond_overheads() {
    // AXLE's overhead vs BS is bounded: per-batch DMA prep and polling
    // quantization. Allow 25% + fixed slack; typically AXLE wins.
    run_prop("axle_vs_bs_bound", 40, |rng| {
        let w = random_workload(rng);
        let mut cfg = SimConfig::m2ndp();
        cfg.seed = rng.next_u64();
        let ax = protocol::run(Protocol::Axle, &w, &cfg);
        let bs = protocol::run(Protocol::Bs, &w, &cfg);
        let slack = 1.25 * bs.total as f64 + 2e8; // +200 μs fixed
        assert!(
            (ax.total as f64) < slack,
            "AXLE {} vs BS {} (workload {:?} iters)",
            ax.total,
            bs.total,
            w.iters.len()
        );
    });
}

#[test]
fn prop_axle_deterministic_per_seed() {
    run_prop("axle_determinism", 30, |rng| {
        let w = random_workload(rng);
        let mut cfg = SimConfig::m2ndp();
        cfg.seed = rng.next_u64();
        let a = protocol::run(Protocol::Axle, &w, &cfg);
        let b = protocol::run(Protocol::Axle, &w, &cfg);
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
        assert_eq!(a.backpressure, b.backpressure);
        assert_eq!(a.dma_batches, b.dma_batches);
    });
}

#[test]
fn prop_jitter_bounded_effect_on_serial_protocols() {
    // Jitter redistributes task durations by ±10%; RP/BS totals must stay
    // within that envelope of the jitter-free run.
    run_prop("jitter_envelope", 30, |rng| {
        let w = random_workload(rng);
        let mut cfg = SimConfig::m2ndp();
        cfg.seed = rng.next_u64();
        cfg.jitter = 0.2;
        let mut flat = cfg.clone();
        flat.jitter = 0.0;
        for p in [Protocol::Rp, Protocol::Bs] {
            let j = protocol::run(p, &w, &cfg);
            let f = protocol::run(p, &w, &flat);
            let ratio = j.total as f64 / f.total as f64;
            assert!((0.85..=1.15).contains(&ratio), "{}: ratio {ratio}", p.label());
        }
    });
}

// ------------------------------------------------------------------
// Shared-link serialization (topology layer, §IV wire model).
// ------------------------------------------------------------------

#[test]
fn prop_shared_link_serializes_two_senders_without_overlap() {
    // Two logical senders interleave send/round_trip calls on one Link
    // (the multi-tenant sharing the topology layer arbitrates). Invariants:
    // wire occupancies never overlap, wire starts are monotone, arrival
    // times are monotone in (global) issue order and per sender.
    use axle::cxl::Link;
    use axle::sim::{transfer_ps, Ps, NS};
    run_prop("shared_link_serialization", 200, |rng| {
        let bw = [1.0, 4.0, 16.0, 32.0][rng.below(4) as usize];
        let rtt = rng.below(500) * NS;
        let mut link = Link::new(rtt, bw);
        link.enable_trace();
        let mut t: Ps = 0;
        let mut arrivals: Vec<Ps> = Vec::new();
        let mut per_sender_last: [Ps; 2] = [0, 0];
        let mut issues: Vec<(Ps, u64)> = Vec::new();
        for _ in 0..rng.range(5, 120) {
            // Global issue clock is nondecreasing (event-time order).
            t += rng.below(2000) * 100;
            let sender = rng.below(2) as usize;
            let bytes = rng.range(1, 1 << 16);
            let arrive = if rng.next_f64() < 0.5 {
                link.send(t, bytes, true)
            } else {
                link.round_trip(t, bytes, true)
            };
            // Arrival monotone in issue order, globally and per sender.
            if let Some(&prev) = arrivals.last() {
                assert!(arrive >= prev, "global arrival order violated");
            }
            assert!(arrive >= per_sender_last[sender], "per-sender arrival order violated");
            per_sender_last[sender] = arrive;
            arrivals.push(arrive);
            issues.push((t, bytes));
        }
        // Wire occupancies: every message traced, no two overlap.
        let trace = link.take_trace();
        assert_eq!(trace.len(), issues.len());
        for (w, &(issue, bytes)) in trace.iter().zip(&issues) {
            assert_eq!(w.bytes, bytes);
            assert!(w.start >= issue, "wire cannot start before issue");
        }
        for pair in trace.windows(2) {
            let end = pair[0].start + transfer_ps(pair[0].bytes, bw);
            assert!(
                pair[1].start >= end,
                "wire overlap: [{}, {}) then start {}",
                pair[0].start,
                end,
                pair[1].start
            );
        }
    });
}

// ------------------------------------------------------------------
// QoS arbitration invariants (topo::fabric, PR-3 policies).
// ------------------------------------------------------------------

mod qos_props {
    use axle::config::QosSpec;
    use axle::sim::transfer_ps;
    use axle::topo::fabric::{arbitrate, arbitrate_qos, FabricMsg};
    use axle::util::prop::run_prop;
    use axle::util::rng::Pcg32;

    fn random_msgs(rng: &mut Pcg32, n_tenants: usize) -> Vec<FabricMsg> {
        let count = rng.range(1, 80) as usize;
        let mut t = 0u64;
        (0..count)
            .map(|_| {
                t += rng.below(50_000);
                FabricMsg {
                    at: t,
                    bytes: rng.range(1, 1 << 16),
                    tenant: rng.below(n_tenants as u64) as u32,
                }
            })
            .collect()
    }

    /// All policies are work-conserving on one wire, so busy periods —
    /// and with them the busy union, aggregate service time, final
    /// free-up and per-tenant message/byte counts — are identical; QoS
    /// only redistributes waits. ("Conservation-consistency with the
    /// FCFS totals.")
    #[test]
    fn prop_qos_policies_share_busy_periods() {
        run_prop("qos_busy_period_invariance", 150, |rng| {
            let n = rng.range(1, 6) as usize;
            let msgs = random_msgs(rng, n);
            let bw = [1.0, 4.0, 16.0][rng.below(3) as usize];
            let weights: Vec<u64> = (0..n).map(|_| rng.range(0, 4)).collect();
            let floors: Vec<f64> = (0..n).map(|_| rng.range(1, 8) as f64 / 4.0).collect();
            let fcfs = arbitrate(msgs.clone(), bw, bw, n);
            for qos in [QosSpec::wrr(weights.clone()), QosSpec::drr(floors.clone())] {
                let out = arbitrate_qos(msgs.clone(), bw, bw, n, &qos);
                let label = qos.policy.label();
                assert_eq!(out.busy.union(), fcfs.busy.union(), "{label}: busy union");
                assert_eq!(out.busy.total(), fcfs.busy.total(), "{label}: busy total");
                assert_eq!(out.wire_free, fcfs.wire_free, "{label}: final free-up");
                assert_eq!(out.messages, fcfs.messages, "{label}: messages");
                assert_eq!(out.bytes, fcfs.bytes, "{label}: bytes");
                // Per-tenant service counts are preserved (every message
                // of every tenant is served exactly once).
                for tenant in 0..n as u32 {
                    let want = msgs.iter().filter(|m| m.tenant == tenant).count();
                    let got = out.order.iter().filter(|&&t| t == tenant).count();
                    assert_eq!(got, want, "{label}: tenant {tenant} service count");
                }
            }
        });
    }

    /// WRR never starves a nonzero-weight tenant: a 1-message mouse
    /// behind hog bursts is served strictly earlier than under FCFS
    /// (which, with everything queued at t = 0, serves the mouse dead
    /// last — it has the highest tenant id).
    #[test]
    fn prop_wrr_mouse_beats_fcfs_tail() {
        run_prop("wrr_no_starvation", 120, |rng| {
            let hogs = rng.range(1, 3) as usize;
            let n = hogs + 1;
            let mouse = hogs as u32;
            let mut msgs = Vec::new();
            for h in 0..hogs as u32 {
                for _ in 0..rng.range(10, 30) {
                    msgs.push(FabricMsg { at: 0, bytes: rng.range(10_000, 100_000), tenant: h });
                }
            }
            msgs.push(FabricMsg { at: 0, bytes: rng.range(100, 1_000), tenant: mouse });
            let mut weights: Vec<u64> = (0..hogs as u64).map(|_| rng.range(1, 3)).collect();
            weights.push(1); // the mouse's nonzero weight
            let fcfs = arbitrate(msgs.clone(), 16.0, 16.0, n);
            let wrr = arbitrate_qos(msgs.clone(), 16.0, 16.0, n, &QosSpec::wrr(weights.clone()));
            // Mouse served within the first Σweights services (one WRR
            // round), far before the hog backlog drains.
            let sum_w: u64 = weights.iter().sum();
            let pos = wrr.order.iter().position(|&t| t == mouse).expect("mouse served");
            assert!(
                (pos as u64) < sum_w,
                "mouse served at position {pos}, round is {sum_w}"
            );
            assert!(
                wrr.waits[mouse as usize] < fcfs.waits[mouse as usize],
                "WRR mouse wait {} must beat FCFS {}",
                wrr.waits[mouse as usize],
                fcfs.waits[mouse as usize]
            );
        });
    }

    /// DRR with equal floors over equal-size packets is exact round-robin
    /// (quantum = packet size): a 1-packet mouse is served within the
    /// first cycle and always beats the FCFS tail.
    #[test]
    fn prop_drr_equal_floors_never_starve() {
        run_prop("drr_no_starvation", 120, |rng| {
            let hogs = rng.range(1, 4) as usize;
            let n = hogs + 1;
            let mouse = hogs as u32;
            let bytes = rng.range(1_000, 50_000);
            let mut msgs = Vec::new();
            for h in 0..hogs as u32 {
                for _ in 0..rng.range(5, 20) {
                    msgs.push(FabricMsg { at: 0, bytes, tenant: h });
                }
            }
            msgs.push(FabricMsg { at: 0, bytes, tenant: mouse });
            let fcfs = arbitrate(msgs.clone(), 16.0, 16.0, n);
            let drr = arbitrate_qos(msgs.clone(), 16.0, 16.0, n, &QosSpec::drr(Vec::new()));
            let pos = drr.order.iter().position(|&t| t == mouse).expect("mouse served");
            assert!(pos < n, "round-robin serves the mouse in cycle one");
            assert!(drr.waits[mouse as usize] < fcfs.waits[mouse as usize]);
            // Sanity: the mouse's wait is at most (n-1) serializations.
            let ser = transfer_ps(bytes, 16.0);
            assert!(drr.waits[mouse as usize] <= (n as u64 - 1) * ser);
        });
    }

    /// FCFS through the QoS entry point is the PR-2 arbiter, bit for bit,
    /// on arbitrary inputs (the dispatcher must never drift).
    #[test]
    fn prop_fcfs_policy_matches_pr2_arbiter() {
        run_prop("fcfs_is_pr2", 150, |rng| {
            let n = rng.range(1, 5) as usize;
            let msgs = random_msgs(rng, n);
            let bw = [1.0, 8.0, 16.0][rng.below(3) as usize];
            let base = [bw, 2.0 * bw][rng.below(2) as usize];
            let a = arbitrate(msgs.clone(), bw, base, n);
            let b = arbitrate_qos(msgs, bw, base, n, &QosSpec::fcfs());
            assert_eq!(a.waits, b.waits);
            assert_eq!(a.order, b.order);
            assert_eq!(a.wire_free, b.wire_free);
            assert_eq!(a.busy.union(), b.busy.union());
            assert_eq!(a.busy.total(), b.busy.total());
            assert_eq!((a.messages, a.bytes), (b.messages, b.bytes));
        });
    }

    /// PU-pool replay: a within-capacity demand set replays with zero
    /// shift; overloading the pool charges only the displaced tenants and
    /// conserves aggregate PU time.
    #[test]
    fn prop_pu_replay_conserves_demand() {
        use axle::topo::fabric::{arbitrate_pus, PuDemand};
        run_prop("pu_replay_conservation", 150, |rng| {
            let n = rng.range(1, 5) as usize;
            let capacity = rng.range(1, 8) as usize;
            let mut t = 0u64;
            let demands: Vec<PuDemand> = (0..rng.range(1, 60))
                .map(|_| {
                    t += rng.below(5_000);
                    PuDemand {
                        at: t,
                        dur: rng.range(1, 20_000),
                        tenant: rng.below(n as u64) as u32,
                    }
                })
                .collect();
            let total: u64 = demands.iter().map(|d| d.dur).sum();
            let out = arbitrate_pus(demands.clone(), capacity, n);
            // Aggregate PU time is conserved; the union never exceeds it.
            assert_eq!(out.busy_total, total);
            assert!(out.busy_union <= total);
            assert_eq!(out.spans, demands.len() as u64);
            // A pool at least as wide as the demand count cannot contend.
            let wide = arbitrate_pus(demands.clone(), demands.len(), n);
            assert_eq!(wide.total_wait(), 0);
            // More capacity never hurts any tenant.
            let wider = arbitrate_pus(demands, capacity + 1, n);
            for i in 0..n {
                assert!(wider.waits[i] <= out.waits[i], "tenant {i} hurt by extra PU");
            }
        });
    }
}

// ------------------------------------------------------------------
// Closed-loop scheduler invariants (sched::driver, PR-4 subsystem).
// ------------------------------------------------------------------

mod sched_props {
    use axle::config::{
        DeviceOverride, PolicyKind, Protocol, QosSpec, SchedSpec, SimConfig, TopologySpec,
    };
    use axle::sched::{run, SchedReport, SchedRun};
    use axle::sim::{Ps, US};
    use axle::util::prop::run_prop;

    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    /// Sweep-line maximum of concurrently open `[open, close)` intervals.
    /// At equal timestamps, closes are applied before opens — exactly the
    /// driver's event order (completions before submissions/admissions).
    fn max_overlap(intervals: &[(Ps, Ps)]) -> usize {
        let mut events: Vec<(Ps, i32)> = Vec::with_capacity(intervals.len() * 2);
        for &(open, close) in intervals {
            events.push((open, 1));
            events.push((close, -1));
        }
        events.sort_by_key(|&(t, delta)| (t, delta));
        let mut cur: i32 = 0;
        let mut max: i32 = 0;
        for (_, delta) in events {
            cur += delta;
            max = max.max(cur);
        }
        max as usize
    }

    /// The closed-loop invariants the subsystem promises:
    /// - a tenant never has more than `depth` outstanding requests;
    /// - a device never serves more than `admit` requests at once;
    /// - per-tenant submissions are non-decreasing (strictly increasing
    ///   with nonzero think time) and completions are monotone under
    ///   window 1;
    /// - every request obeys the slowdown decomposition identity;
    /// - exactly `streams x requests` requests run, each exactly once.
    #[test]
    fn prop_closed_loop_window_admission_and_monotonicity() {
        let cfg = SimConfig::m2ndp();
        run_prop("closed_loop_invariants", 10, |rng| {
            let streams = rng.range(1, 4) as usize;
            let devices = rng.range(1, 3) as usize;
            let depth = rng.range(1, 3) as usize;
            let admit = rng.range(1, 2) as usize;
            let requests = rng.range(1, 3) as usize;
            let think = rng.below(2) * US;
            let policy = [
                PolicyKind::Static(Protocol::Axle),
                PolicyKind::Heuristic,
                PolicyKind::Oracle,
            ][rng.below(3) as usize];
            let mut topo = TopologySpec { devices, ..TopologySpec::default() };
            if rng.below(2) == 1 {
                topo.fabric_bw_gbps = Some(cfg.cxl_bw_gbps);
            }
            if devices > 1 && rng.below(2) == 1 {
                topo = topo
                    .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
            }
            let spec = SchedSpec::new(streams)
                .with_workloads(vec!['a', 'f'])
                .with_policy(policy)
                .with_depth(depth)
                .with_admit(admit)
                .with_requests(requests)
                .with_think(think)
                .with_seed(rng.next_u64());
            let r = run_sched(&cfg, &topo, &spec, 2);

            assert_eq!(r.requests.len(), streams * requests);
            for t in 0..streams as u32 {
                let of_t: Vec<_> = r.requests.iter().filter(|q| q.tenant == t).collect();
                assert_eq!(of_t.len(), requests);
                // Indices 0..requests, in order (report sorts by index).
                for (j, q) in of_t.iter().enumerate() {
                    assert_eq!(q.index as usize, j);
                }
                // Submissions never go back in time; think spaces them.
                for w in of_t.windows(2) {
                    assert!(w[1].submit >= w[0].submit);
                    if think > 0 {
                        assert!(w[1].submit > w[0].submit);
                    }
                }
                // Window: never more than `depth` outstanding.
                let windows: Vec<(Ps, Ps)> =
                    of_t.iter().map(|q| (q.submit, q.completion)).collect();
                assert!(max_overlap(&windows) <= depth, "tenant {t} window exceeded");
                // Window 1 serializes the tenant: completions monotone.
                if depth == 1 {
                    for w in of_t.windows(2) {
                        assert!(w[1].completion >= w[0].completion);
                        assert!(w[1].submit >= w[0].completion);
                    }
                }
            }
            // Per-device admission: never more than `admit` in service.
            for d in 0..devices as u32 {
                let service: Vec<(Ps, Ps)> = r
                    .requests
                    .iter()
                    .filter(|q| q.device == d)
                    .map(|q| (q.admit, q.completion))
                    .collect();
                assert!(max_overlap(&service) <= admit, "device {d} admission exceeded");
            }
            // Decomposition identity and sane ordering per request.
            for q in &r.requests {
                assert!(q.admit >= q.submit);
                assert!(q.completion >= q.admit + q.solo);
                assert_eq!(q.total(), q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait);
                assert!(q.slowdown() >= 1.0);
            }
            assert_eq!(r.makespan, r.requests.iter().map(|q| q.completion).max().unwrap());
        });
    }

    /// Online QoS + priority admission preserve the closed-loop
    /// contract. On random small scenarios (random priorities, a WRR
    /// weight vector that includes a zero-weight best-effort tenant, a
    /// DRR floor vector that includes a zero floor):
    /// - **no starvation** — every request completes exactly once under
    ///   WRR and DRR, zero-weight/zero-floor tenants included, and the
    ///   decomposition identity holds per request;
    /// - **busy-time invariance (work conservation)** — with a static
    ///   policy the same message multiset crosses the same wires, so
    ///   total bytes and link busy time match the FCFS calendars
    ///   exactly; QoS only redistributes who waits.
    #[test]
    fn prop_online_qos_no_starvation_and_busy_invariance() {
        let cfg = SimConfig::m2ndp();
        run_prop("online_qos_invariants", 6, |rng| {
            let streams = rng.range(2, 4) as usize;
            let requests = rng.range(1, 3) as usize;
            let depth = rng.range(1, 3) as usize;
            let admit = rng.range(1, 3) as usize;
            let fabric = rng.below(2) == 1;
            let mut priorities = Vec::with_capacity(streams);
            for _ in 0..streams {
                priorities.push(rng.below(3) as u32);
            }
            let spec = SchedSpec::new(streams)
                .with_workloads(vec!['a', 'f'])
                .with_policy(PolicyKind::Static(Protocol::Axle))
                .with_depth(depth)
                .with_admit(admit)
                .with_requests(requests)
                .with_priorities(priorities)
                .with_seed(rng.next_u64());
            let mk = |qos: QosSpec| {
                let mut topo = TopologySpec { devices: 1, ..TopologySpec::default() };
                if fabric {
                    topo.fabric_bw_gbps = Some(cfg.cxl_bw_gbps);
                }
                topo.with_qos(qos)
            };
            let fcfs = run_sched(&cfg, &mk(QosSpec::fcfs()), &spec, 2);
            let mut weights = vec![0u64];
            let mut floors = vec![0.0f64];
            for _ in 1..streams {
                weights.push(rng.range(1, 5));
                floors.push(rng.range(1, 5) as f64 / 4.0);
            }
            for qos in [QosSpec::wrr(weights.clone()), QosSpec::drr(floors.clone())] {
                let label = qos.policy.label();
                let r = run_sched(&cfg, &mk(qos), &spec, 2);
                assert_eq!(r.requests.len(), streams * requests, "{label}: starvation");
                for q in &r.requests {
                    assert_eq!(
                        q.total(),
                        q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait,
                        "{label}: decomposition"
                    );
                }
                assert_eq!(r.devices[0].bytes, fcfs.devices[0].bytes, "{label}: bytes");
                assert_eq!(r.devices[0].link_busy, fcfs.devices[0].link_busy, "{label}: busy");
                assert_eq!(r.fabric.bytes, fcfs.fabric.bytes, "{label}: fabric bytes");
                assert_eq!(r.fabric.busy, fcfs.fabric.busy, "{label}: fabric busy");
            }
        });
    }
}

// ------------------------------------------------------------------
// Fault injection + recovery invariants (sched::fault, PR-6 subsystem).
// ------------------------------------------------------------------

mod fault_props {
    use axle::config::{
        DeviceOverride, FaultEvent, FaultSpec, PolicyKind, Protocol, SchedSpec, SimConfig,
        TopologySpec,
    };
    use axle::sched::{run, SchedReport, SchedRun};
    use axle::sim::US;
    use axle::util::prop::run_prop;
    use axle::util::rng::Pcg32;

    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    fn two_device_topo(cfg: &SimConfig) -> TopologySpec {
        TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
    }

    fn random_spec(rng: &mut Pcg32) -> SchedSpec {
        SchedSpec::new(rng.range(1, 4) as usize)
            .with_workloads(vec!['a', 'f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_depth(rng.range(1, 3) as usize)
            .with_admit(rng.range(1, 3) as usize)
            .with_requests(rng.range(1, 3) as usize)
            .with_seed(rng.next_u64())
    }

    /// A random, always-valid fault schedule over the two-device
    /// topology: permanent failures only ever target device 0 (so device
    /// 1 survives and the spec always validates), stalls and
    /// degradations land anywhere, and windows — placed inside the
    /// fault-free run's horizon so they actually bite — may be
    /// zero-length.
    fn random_faults(rng: &mut Pcg32, horizon: u64) -> FaultSpec {
        let n = rng.range(1, 4) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at = rng.below(horizon.max(1));
            let dur = rng.below(300) * US;
            let device = rng.below(2) as u32;
            let factor = 1.0 + rng.below(8) as f64;
            events.push(match rng.below(4) {
                0 => FaultEvent::fail(0, at),
                1 => FaultEvent::stall(device, at, at + dur),
                2 => FaultEvent::degrade_pus(device, at, at + dur, factor),
                _ => FaultEvent::degrade_link(device, at, at + dur, factor),
            });
        }
        let mut spec = FaultSpec::with(events);
        spec.max_retries = rng.range(1, 5) as u32;
        spec.backoff = rng.range(1, 100) * US;
        spec.timeout_factor = 2.0 + rng.below(8) as f64;
        spec
    }

    /// Under arbitrary fault schedules the run never loses or hangs a
    /// request: exactly `streams x requests` requests come back, each
    /// either completed or explicitly failed after exhausting the retry
    /// budget, and every completed request obeys the fault-extended
    /// decomposition identity
    /// `total = queue_wait + retry_wait + solo + wire_wait + pu_wait`.
    #[test]
    fn prop_no_request_lost_under_random_faults() {
        let cfg = SimConfig::m2ndp();
        run_prop("fault_conservation", 12, |rng| {
            let topo = two_device_topo(&cfg);
            let spec = random_spec(rng);
            let base = run_sched(&cfg, &topo, &spec, 2);
            let faults = random_faults(rng, base.makespan.max(1));
            let max_retries = faults.max_retries;
            let r = run_sched(&cfg, &topo, &spec.clone().with_faults(faults), 2);

            assert_eq!(r.requests.len(), base.requests.len(), "request lost or duplicated");
            let failed = r.requests.iter().filter(|q| q.failed).count();
            assert_eq!(failed, r.failed_requests, "failed-request count drifted");
            for q in &r.requests {
                assert!(q.admit >= q.submit);
                assert!(q.completion >= q.admit);
                assert!(!q.placed_on.is_empty());
                if q.failed {
                    // Dropped exactly at the retry budget, with its
                    // waits zeroed out of the aggregates.
                    assert_eq!(q.retries, max_retries + 1);
                    assert_eq!(q.admit, q.completion);
                } else {
                    assert_eq!(
                        q.total(),
                        q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait,
                        "decomposition identity under faults"
                    );
                }
            }
            // Lost work is reported iff some in-service attempt died.
            let lost = r.lost_wire + r.lost_pu;
            let displaced: u32 = r.faults.iter().map(|f| f.displaced).sum();
            if lost > 0 {
                assert!(displaced > 0, "lost work without displacement");
            }
        });
    }

    /// A schedule of only zero-duration windows is bit-identical to the
    /// fault-free run: the engine schedules no fault transitions at all,
    /// so every request record — serialized, byte for byte — and every
    /// aggregate matches; only the all-zero fault outcome rows differ.
    #[test]
    fn prop_zero_duration_windows_are_bit_identical_to_fault_free() {
        let cfg = SimConfig::m2ndp();
        run_prop("fault_zero_window_identity", 12, |rng| {
            let topo = two_device_topo(&cfg);
            let spec = random_spec(rng);
            let base = run_sched(&cfg, &topo, &spec, 2);
            let n = rng.range(1, 3) as usize;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                let at = rng.below(base.makespan.max(1));
                let device = rng.below(2) as u32;
                events.push(match rng.below(3) {
                    0 => FaultEvent::stall(device, at, at),
                    1 => FaultEvent::degrade_pus(device, at, at, 8.0),
                    _ => FaultEvent::degrade_link(device, at, at, 8.0),
                });
            }
            let r = run_sched(&cfg, &topo, &spec.clone().with_faults(FaultSpec::with(events)), 2);

            assert_eq!(base.requests.len(), r.requests.len());
            for (a, b) in base.requests.iter().zip(&r.requests) {
                assert_eq!(
                    a.to_json().to_string(),
                    b.to_json().to_string(),
                    "request record drifted under a zero-duration window"
                );
            }
            assert_eq!(base.makespan, r.makespan);
            assert_eq!(base.p50_slowdown.to_bits(), r.p50_slowdown.to_bits());
            assert_eq!(base.p99_slowdown.to_bits(), r.p99_slowdown.to_bits());
            assert_eq!(base.max_slowdown.to_bits(), r.max_slowdown.to_bits());
            assert_eq!(base.host_busy, r.host_busy);
            assert_eq!(base.ccm_busy, r.ccm_busy);
            // The outcome rows exist but report nothing happening.
            assert_eq!(r.faults.len(), n);
            for row in &r.faults {
                assert_eq!((row.displaced, row.recover), (0, 0));
                assert_eq!((row.lost_wire, row.lost_pu), (0, 0));
            }
            assert_eq!(r.failed_requests, 0);
        });
    }
}

// ------------------------------------------------------------------
// PR-7 event-core structures: the coalesced wire calendar against the
// PR-6 per-message reference, and sketch percentiles against exact.
// ------------------------------------------------------------------

mod event_core_props {
    use axle::metrics::{percentile, QuantileSketch};
    use axle::sched::driver::LinkCalendar;
    use axle::sim::Ps;
    use axle::util::prop::run_prop;

    /// The PR-6 wire calendar, kept verbatim as the test oracle: one
    /// map entry per placed message, linear gap walk from the issue
    /// instant. The engine replaced it with the coalesced-interval
    /// [`LinkCalendar`]; this reference pins every observable.
    #[derive(Default)]
    struct RefCalendar {
        /// `start → end`, one entry per message (non-overlapping).
        msgs: std::collections::BTreeMap<Ps, Ps>,
    }

    impl RefCalendar {
        fn place(&mut self, issue: Ps, dur: Ps) -> Ps {
            if dur == 0 {
                return issue;
            }
            let mut t = issue;
            for (&s, &e) in &self.msgs {
                if e <= t {
                    continue;
                }
                if s >= t + dur {
                    break;
                }
                t = e;
            }
            self.msgs.insert(t, t + dur);
            t
        }

        fn tail(&self) -> Ps {
            self.msgs.values().copied().max().unwrap_or(0)
        }

        fn msgs(&self) -> u64 {
            self.msgs.len() as u64
        }

        fn busy_union(&self) -> Ps {
            self.msgs.iter().map(|(&s, &e)| e - s).sum()
        }

        /// Mirror of the engine's truncate: future messages vanish, a
        /// straddler is clipped but keeps its message count (it really
        /// started before the cut).
        fn truncate(&mut self, now: Ps) {
            self.msgs.retain(|&s, _| s < now);
            for e in self.msgs.values_mut() {
                *e = (*e).min(now);
            }
        }
    }

    /// Random place/truncate sequences: the coalesced calendar must
    /// grant the same start instant for every placement and agree with
    /// the reference on tail, message count and busy union after every
    /// operation — including backfills before the tail, abutting merges
    /// and zero-length transfers.
    #[test]
    fn prop_coalesced_calendar_matches_pr6_reference() {
        run_prop("calendar_vs_reference", 150, |rng| {
            let mut cal = LinkCalendar::default();
            let mut oracle = RefCalendar::default();
            for _ in 0..rng.range(10, 300) {
                if rng.next_f64() < 0.9 {
                    let issue = rng.below(cal.tail() + 100);
                    let dur = rng.below(50); // zero-length included
                    let a = cal.place(issue, dur);
                    let b = oracle.place(issue, dur);
                    assert_eq!(a, b, "placement start drifted");
                } else {
                    let now = rng.below(cal.tail() + 100);
                    cal.truncate(now);
                    oracle.truncate(now);
                }
                assert_eq!(cal.tail(), oracle.tail());
                assert_eq!(cal.msgs(), oracle.msgs());
                assert_eq!(cal.busy_union(), oracle.busy_union());
            }
        });
    }

    /// On random slowdown-like samples spanning several octaves the
    /// sketch answers p0/p100 exactly (bit for bit) and every interior
    /// quantile within one sub-bucket (relative error ≤ 2⁻⁷) of the
    /// retained-vector [`percentile`] under the same rank rule.
    #[test]
    fn prop_sketch_quantiles_track_exact_percentiles() {
        run_prop("sketch_percentile_error", 120, |rng| {
            let n = rng.range(1, 500) as usize;
            let mut xs = Vec::with_capacity(n);
            let mut sk = QuantileSketch::new();
            for _ in 0..n {
                let v = 1.0 + rng.next_f64() * f64::exp2(rng.below(10) as f64);
                xs.push(v);
                sk.record(v);
            }
            assert_eq!(sk.count(), n as u64);
            assert_eq!(sk.quantile(0.0).to_bits(), percentile(&xs, 0.0).to_bits());
            assert_eq!(sk.quantile(100.0).to_bits(), percentile(&xs, 100.0).to_bits());
            for q in [10.0, 25.0, 50.0, 75.0, 90.0, 99.0] {
                let exact = percentile(&xs, q);
                let approx = sk.quantile(q);
                assert!(
                    (approx - exact).abs() <= exact / 128.0,
                    "q={q}: sketch {approx} vs exact {exact}"
                );
            }
        });
    }

    /// Counter merge is order-free and lossless: recording a sample
    /// split across several sketches and merging them answers every
    /// quantile bit-identically to one sketch that saw everything.
    #[test]
    fn prop_sketch_merge_is_bit_identical_to_single() {
        run_prop("sketch_merge_identity", 120, |rng| {
            let parts = rng.range(2, 5) as usize;
            let mut whole = QuantileSketch::new();
            let mut shards = vec![QuantileSketch::new(); parts];
            for _ in 0..rng.range(1, 400) {
                let v = 0.5 + rng.next_f64() * 100.0;
                whole.record(v);
                shards[rng.below(parts as u64) as usize].record(v);
            }
            // Fold in a rotated order to exercise order-freedom.
            let start = rng.below(parts as u64) as usize;
            let mut merged = QuantileSketch::new();
            for i in 0..parts {
                merged.merge(&shards[(start + i) % parts]);
            }
            assert_eq!(merged.count(), whole.count());
            for q in [0.0, 10.0, 50.0, 99.0, 100.0] {
                assert_eq!(merged.quantile(q).to_bits(), whole.quantile(q).to_bits(), "q={q}");
            }
        });
    }
}

// ------------------------------------------------------------------
// PR-8 intra-request pipelining: stage-graph structure and the chunked
// closed loop (protocol::{bs,axle}::stage_graph, sched --chunks).
// ------------------------------------------------------------------

mod pipeline_props {
    use axle::config::{
        PipelineMode, PipelineSpec, PolicyKind, Protocol, SchedSpec, SimConfig, TopologySpec,
    };
    use axle::protocol::{self, Lane, StageGraph};
    use axle::sched::{run, SchedReport, SchedRun};
    use axle::util::prop::run_prop;

    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    /// Ancestor sets over the `after` DAG (indices are emitted in
    /// topological order, so one forward pass suffices).
    fn ancestors(g: &StageGraph) -> Vec<Vec<bool>> {
        let n = g.stages.len();
        let mut anc = vec![vec![false; n]; n];
        for i in 0..n {
            for &p in &g.stages[i].after {
                let p = p as usize;
                anc[i][p] = true;
                for j in 0..n {
                    if anc[p][j] {
                        anc[i][j] = true;
                    }
                }
            }
        }
        anc
    }

    /// Structural invariants every emitted stage graph must satisfy:
    /// - per lane, the stage ranges partition `[0, len)` contiguously in
    ///   chunk order (byte/flop totals are conserved by construction)
    ///   and empty ranges are never emitted;
    /// - `after` edges point strictly backwards (emission order is
    ///   topological) and chunk tags are non-decreasing;
    /// - **lane precedence**: each lane's consecutive stages are
    ///   ordered by an `after` path, so no stage can start before its
    ///   lane predecessor finishes;
    /// - BS graphs are barrier chains (`serial`), AXLE graphs overlap
    ///   (`!serial`), and `stage_graph_for` honors a forced mode.
    #[test]
    fn prop_stage_graphs_partition_lanes_and_order_predecessors() {
        run_prop("stage_graph_structure", 200, |rng| {
            let chunks = rng.range(1, 12) as u32;
            let mem_len = rng.below(40) as usize;
            let io_len = rng.below(40) as usize;
            let ccm_len = rng.below(40) as usize;
            let bs = protocol::bs::stage_graph(chunks, mem_len, io_len, ccm_len);
            let ax = protocol::axle::stage_graph(chunks, mem_len, io_len, ccm_len);
            assert!(bs.serial);
            assert!(!ax.serial);
            for g in [&bs, &ax] {
                assert_eq!(g.chunks, chunks);
                let mut last_chunk = 0u32;
                for (i, st) in g.stages.iter().enumerate() {
                    assert!(st.lo < st.hi, "empty stage emitted");
                    assert!(st.chunk < chunks);
                    assert!(st.chunk >= last_chunk, "chunk tags go backwards");
                    last_chunk = st.chunk;
                    for &p in &st.after {
                        assert!((p as usize) < i, "forward dependency edge");
                    }
                }
                let anc = ancestors(g);
                for (lane, len) in
                    [(Lane::MemWire, mem_len), (Lane::IoWire, io_len), (Lane::Ccm, ccm_len)]
                {
                    let of_lane: Vec<usize> = (0..g.stages.len())
                        .filter(|&i| g.stages[i].lane == lane)
                        .collect();
                    // Contiguous partition of [0, len) in chunk order.
                    let mut cursor = 0u32;
                    for &i in &of_lane {
                        assert_eq!(g.stages[i].lo, cursor, "gap or overlap in lane ranges");
                        cursor = g.stages[i].hi;
                    }
                    assert_eq!(cursor as usize, len, "lane items dropped or duplicated");
                    // Lane precedence via the after DAG.
                    for w in of_lane.windows(2) {
                        assert!(
                            anc[w[1]][w[0]],
                            "lane stage {} not ordered after predecessor {}",
                            w[1],
                            w[0]
                        );
                    }
                }
            }
            // Every chunk_range is sane on its own, any k, any len.
            let len = rng.below(200) as usize;
            let k = rng.below(chunks as u64) as u32;
            let (lo, hi) = StageGraph::chunk_range(len, chunks, k);
            assert!(lo <= hi && hi as usize <= len);
            // Forced modes override the per-protocol default shape.
            for proto in [Protocol::Bs, Protocol::Axle] {
                let ser =
                    protocol::stage_graph_for(proto, PipelineMode::Serial, chunks, 5, 5, 5);
                let pip =
                    protocol::stage_graph_for(proto, PipelineMode::Pipelined, chunks, 5, 5, 5);
                assert!(ser.serial && !pip.serial, "{proto:?}");
            }
        });
    }

    /// The chunked closed loop conserves work and keeps the request
    /// algebra at every chunk count: the same byte multiset crosses the
    /// same wires as the unchunked run (equal device/fabric bytes and
    /// link busy time), the request count is exact, every request's
    /// decomposition identity holds, and `completion >= admit + solo`.
    #[test]
    fn prop_chunked_runs_conserve_bytes_and_decomposition() {
        let cfg = SimConfig::m2ndp();
        run_prop("chunked_conservation", 4, |rng| {
            let streams = rng.range(2, 3) as usize;
            let devices = rng.range(1, 2) as usize;
            let requests = rng.range(1, 2) as usize;
            let admit = rng.range(1, 2) as usize;
            let depth = rng.range(1, 2) as usize;
            let mut topo = TopologySpec { devices, ..TopologySpec::default() };
            if rng.below(2) == 1 {
                topo.fabric_bw_gbps = Some(cfg.cxl_bw_gbps);
            }
            let base = SchedSpec::new(streams)
                .with_workloads(vec!['a', 'f'])
                .with_policy(PolicyKind::Static(Protocol::Axle))
                .with_depth(depth)
                .with_admit(admit)
                .with_requests(requests)
                .with_seed(rng.next_u64());
            let whole = run_sched(&cfg, &topo, &base, 2);
            let bytes = |r: &axle::sched::SchedReport| {
                r.devices.iter().map(|d| d.bytes).sum::<u64>()
            };
            let busy = |r: &axle::sched::SchedReport| {
                r.devices.iter().map(|d| d.link_busy).sum::<u64>()
            };
            for chunks in [2u32, 3, 4, 8] {
                let spec =
                    base.clone().with_pipeline(PipelineSpec::with_chunks(chunks));
                let r = run_sched(&cfg, &topo, &spec, 2);
                assert_eq!(r.requests.len(), streams * requests, "chunks={chunks}");
                assert_eq!(bytes(&r), bytes(&whole), "chunks={chunks}: bytes drifted");
                assert_eq!(busy(&r), busy(&whole), "chunks={chunks}: busy drifted");
                assert_eq!(r.fabric.bytes, whole.fabric.bytes, "chunks={chunks}");
                for q in &r.requests {
                    assert!(q.admit >= q.submit, "chunks={chunks}");
                    assert!(q.completion >= q.admit + q.solo, "chunks={chunks}");
                    assert_eq!(
                        q.total(),
                        q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait,
                        "chunks={chunks}: decomposition"
                    );
                    assert!(q.slowdown() >= 1.0, "chunks={chunks}");
                }
                assert_eq!(
                    r.makespan,
                    r.requests.iter().map(|q| q.completion).max().unwrap(),
                    "chunks={chunks}"
                );
            }
        });
    }

    /// On a contention-free device (one tenant, window 1 — requests
    /// never overlap on any resource) chunking is provably free: every
    /// stage delay is zero, so the chunked run reproduces the unchunked
    /// run byte for byte, and host + CCM idle are monotone
    /// non-increasing from chunks 1 → 2 (here: exactly equal).
    #[test]
    fn prop_contention_free_chunking_is_free_and_idle_monotone() {
        let cfg = SimConfig::m2ndp();
        run_prop("contention_free_chunking", 4, |rng| {
            let devices = rng.range(1, 2) as usize;
            let requests = rng.range(2, 3) as usize;
            let annot = ['a', 'e', 'f', 'i'][rng.below(4) as usize];
            let topo = TopologySpec { devices, ..TopologySpec::default() };
            let base = SchedSpec::new(1)
                .with_workloads(vec![annot])
                .with_policy(PolicyKind::Static(Protocol::Axle))
                .with_depth(1)
                .with_requests(requests)
                .with_seed(rng.next_u64());
            let one = run_sched(&cfg, &topo, &base, 2);
            let two = run_sched(
                &cfg,
                &topo,
                &base.clone().with_pipeline(PipelineSpec::with_chunks(2)),
                2,
            );
            for q in one.requests.iter().chain(&two.requests) {
                assert_eq!(q.queue_wait(), 0);
                assert_eq!(q.wire_wait(), 0);
                assert_eq!(q.pu_wait, 0);
            }
            assert!(two.host_idle_frac() <= one.host_idle_frac());
            assert!(two.ccm_idle_frac() <= one.ccm_idle_frac());
            assert_eq!(one.to_json().to_string(), two.to_json().to_string());
        });
    }
}

// ------------------------------------------------------------------
// Deterministic event tracing (trace::, observability PR).
// ------------------------------------------------------------------

mod trace_props {
    use axle::config::{
        DeviceOverride, FaultEvent, FaultSpec, PipelineSpec, PolicyKind, Protocol, QosSpec,
        SchedSpec, SimConfig, TopologySpec, TraceSpec,
    };
    use axle::sched::{run, SchedReport, SchedRun};
    use axle::sim::US;
    use axle::util::prop::run_prop;
    use axle::util::rng::Pcg32;

    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    fn run_sched_traced(
        cfg: &SimConfig,
        topo: &TopologySpec,
        spec: &SchedSpec,
        jobs: usize,
    ) -> (SchedReport, Option<axle::trace::Trace>) {
        let out = run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs));
        (out.report, out.trace)
    }

    fn random_topo(cfg: &SimConfig, rng: &mut Pcg32) -> TopologySpec {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
        match rng.below(3) {
            0 => topo,
            1 => topo.with_qos(QosSpec::wrr(vec![rng.range(1, 8) as u32, 1])),
            _ => topo.with_qos(QosSpec::drr(vec![0.75, 0.25])),
        }
    }

    fn random_spec(rng: &mut Pcg32) -> SchedSpec {
        let spec = SchedSpec::new(rng.range(1, 4) as usize)
            .with_workloads(vec!['a', 'e', 'i'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_depth(rng.range(1, 3) as usize)
            .with_admit(rng.range(1, 3) as usize)
            .with_requests(rng.range(1, 3) as usize)
            .with_priorities(vec![1, 0])
            .with_seed(rng.next_u64());
        if rng.below(2) == 0 {
            spec.with_pipeline(PipelineSpec::with_chunks(rng.range(2, 5) as u32))
        } else {
            spec
        }
    }

    /// One random fault event, always valid on the two-device topology
    /// (permanent failures only target device 0 so device 1 survives).
    fn random_fault(rng: &mut Pcg32, horizon: u64) -> FaultSpec {
        let at = rng.below(horizon.max(1));
        let dur = rng.below(200) * US;
        let factor = 1.0 + rng.below(6) as f64;
        FaultSpec::with(vec![match rng.below(4) {
            0 => FaultEvent::fail(0, at),
            1 => FaultEvent::stall(rng.below(2) as u32, at, at + dur),
            2 => FaultEvent::degrade_pus(rng.below(2) as u32, at, at + dur, factor),
            _ => FaultEvent::degrade_link(rng.below(2) as u32, at, at + dur, factor),
        }])
    }

    /// The tracer's master invariants, under random specs, arbitration,
    /// chunking, and single-event fault schedules:
    ///
    /// 1. observation-only — the traced report's JSON dump (every f64
    ///    included) is byte-identical to the untraced run;
    /// 2. well-formed — `trace::validate` reconciles the event stream
    ///    against the report's conserved aggregates;
    /// 3. telemetry conserves busy time — the windowed CCM busy and
    ///    per-device wire busy re-derived from the trace equal the
    ///    report's own counters exactly (integer picoseconds).
    #[test]
    fn prop_tracing_observation_only_and_conserving() {
        let cfg = SimConfig::m2ndp();
        run_prop("trace_invariants", 8, |rng| {
            let topo = random_topo(&cfg, rng);
            let mut spec = random_spec(rng);
            if rng.below(2) == 0 {
                let base = run_sched(&cfg, &topo, &spec, 2);
                spec = spec.with_faults(random_fault(rng, base.makespan.max(1)));
            }
            let jobs = rng.range(1, 3) as usize;
            let plain = run_sched(&cfg, &topo, &spec, jobs);
            let (traced, tr) = run_sched_traced(
                &cfg,
                &topo,
                &spec.clone().with_trace(TraceSpec::default()),
                jobs,
            );
            assert_eq!(
                plain.to_json().to_string(),
                traced.to_json().to_string(),
                "tracing flipped a result bit"
            );
            let tr = tr.expect("trace spec is set");
            axle::trace::validate(&tr, &traced)
                .unwrap_or_else(|e| panic!("trace does not reconcile: {e}"));

            // Telemetry window sums conserve the report's busy counters.
            let buckets = rng.range(1, 32) as u32;
            let tel = axle::trace::telemetry::windows(&tr, buckets, traced.makespan);
            let ccm: u64 = tel.windows.iter().map(|w| w.ccm_busy).sum();
            assert_eq!(ccm, traced.ccm_busy, "windowed CCM busy drifted");
            let wire: u64 = tel.windows.iter().map(|w| w.wire_busy).sum();
            let link: u64 = traced.devices.iter().map(|d| d.link_busy).sum();
            assert_eq!(wire, link, "windowed wire busy drifted");
            let done: u32 = tel.windows.iter().map(|w| w.completions).sum();
            assert_eq!(
                done as usize,
                traced.requests.iter().filter(|q| !q.failed).count(),
                "windowed completions drifted"
            );
        });
    }
}

// ------------------------------------------------------------------
// Learned decider (sched::learn, PR-10 subsystem).
// ------------------------------------------------------------------

mod learn_props {
    use axle::config::{
        DeviceOverride, FaultEvent, FaultSpec, PolicyKind, SchedSpec, SimConfig, TopologySpec,
    };
    use axle::sched::learn::explore_draw;
    use axle::sched::{run, ArmEstimator, SchedReport, SchedRun};
    use axle::sim::US;
    use axle::util::prop::run_prop;
    use axle::util::rng::Pcg32;

    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    /// Estimator updates are order-free: folding a random observation
    /// multiset in one pass, or splitting it across a random number of
    /// shard-local estimators (in shuffled order) and merging those in a
    /// random order, lands on the identical `(count, total)` state —
    /// the exact identity the `--jobs` shard merge leans on.
    #[test]
    fn prop_estimator_shard_merge_is_order_free() {
        run_prop("learn_estimator_merge", 200, |rng| {
            let n = rng.range(1, 64) as usize;
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                samples.push(rng.below(1_000_000) * 1_000);
            }
            let mut serial = ArmEstimator::default();
            for &s in &samples {
                serial.observe(s);
            }
            // Deal the samples onto `shards` estimators round-robin
            // after a Fisher-Yates shuffle, then merge in random order.
            let mut order: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                order.swap(i, rng.below(i as u64 + 1) as usize);
            }
            let shards = rng.range(1, 8) as usize;
            let mut parts = vec![ArmEstimator::default(); shards];
            for (k, &i) in order.iter().enumerate() {
                parts[k % shards].observe(samples[i]);
            }
            let mut merged = ArmEstimator::default();
            while !parts.is_empty() {
                let pick = rng.below(parts.len() as u64) as usize;
                merged.merge(&parts.swap_remove(pick));
            }
            assert_eq!(merged, serial, "shard merge drifted from the serial fold");
            assert_eq!(merged.mean(0), serial.mean(0));
        });
    }

    /// The epsilon-greedy draw over random seeds/tenants/indices:
    /// always explores an unvisited arm set (`visits == 0`), never
    /// explores with `--explore 0`, and is monotone in `visits` — the
    /// exploration rate only ever decays.
    #[test]
    fn prop_explore_draw_decays_and_respects_bounds() {
        run_prop("learn_explore_decay", 200, |rng| {
            let seed = rng.next_u64();
            let tenant = rng.below(1 << 16) as usize;
            let index = rng.next_u64() >> 20;
            let explore = rng.range(1, 64) as u32;
            assert!(explore_draw(seed, tenant, index, 0, explore), "visits=0 must explore");
            assert!(!explore_draw(seed, tenant, index, 0, 0), "explore=0 must never explore");
            let mut was = true;
            let mut visits = 0u64;
            while visits < 1 << 16 {
                let now = explore_draw(seed, tenant, index, visits, explore);
                assert!(was || !now, "exploration resumed at visits={visits}");
                assert!(!explore_draw(seed, tenant, index, visits, 0));
                was = now;
                visits += rng.range(1, 64);
            }
        });
    }

    fn two_device_topo(cfg: &SimConfig) -> TopologySpec {
        TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
    }

    /// The same always-valid random fault schedule `fault_props` uses
    /// (permanent failures only target device 0 so device 1 survives).
    fn random_faults(rng: &mut Pcg32, horizon: u64) -> FaultSpec {
        let n = rng.range(1, 4) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at = rng.below(horizon.max(1));
            let dur = rng.below(300) * US;
            let device = rng.below(2) as u32;
            let factor = 1.0 + rng.below(8) as f64;
            events.push(match rng.below(4) {
                0 => FaultEvent::fail(0, at),
                1 => FaultEvent::stall(device, at, at + dur),
                2 => FaultEvent::degrade_pus(device, at, at + dur, factor),
                _ => FaultEvent::degrade_link(device, at, at + dur, factor),
            });
        }
        let mut spec = FaultSpec::with(events);
        spec.max_retries = rng.range(1, 5) as u32;
        spec.backoff = rng.range(1, 100) * US;
        spec.timeout_factor = 2.0 + rng.below(8) as f64;
        spec
    }

    /// The learned decider preserves the closed-loop conservation
    /// contract under arbitrary fault schedules: exactly
    /// `streams x requests` requests come back, each completed or
    /// explicitly failed at the retry budget, the decomposition
    /// identity holds, and the run stays deterministic.
    #[test]
    fn prop_learned_never_loses_requests_under_random_faults() {
        let cfg = SimConfig::m2ndp();
        run_prop("learn_fault_conservation", 10, |rng| {
            let topo = two_device_topo(&cfg);
            let spec = SchedSpec::new(rng.range(1, 4) as usize)
                .with_workloads(vec!['a', 'f'])
                .with_policy(PolicyKind::Learned)
                .with_explore(rng.below(16) as u32)
                .with_depth(rng.range(1, 3) as usize)
                .with_admit(rng.range(1, 3) as usize)
                .with_requests(rng.range(1, 3) as usize)
                .with_seed(rng.next_u64());
            let base = run_sched(&cfg, &topo, &spec, 2);
            let faults = random_faults(rng, base.makespan.max(1));
            let max_retries = faults.max_retries;
            let fspec = spec.clone().with_faults(faults);
            let r = run_sched(&cfg, &topo, &fspec, 2);

            assert_eq!(r.requests.len(), base.requests.len(), "request lost or duplicated");
            let failed = r.requests.iter().filter(|q| q.failed).count();
            assert_eq!(failed, r.failed_requests, "failed-request count drifted");
            for q in &r.requests {
                assert!(q.admit >= q.submit);
                assert!(q.completion >= q.admit);
                assert!(!q.placed_on.is_empty());
                if q.failed {
                    assert_eq!(q.retries, max_retries + 1);
                    assert_eq!(q.admit, q.completion);
                } else {
                    assert_eq!(
                        q.total(),
                        q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait,
                        "decomposition identity under faults"
                    );
                }
            }
            // Stateful learning must not cost determinism: the same
            // faulted spec replays byte-identically.
            let again = run_sched(&cfg, &topo, &fspec, 2);
            assert_eq!(
                r.to_json().to_string(),
                again.to_json().to_string(),
                "learned faulted run is not reproducible"
            );
        });
    }
}
