//! Topology-layer regression: the resource refactor must not move a
//! single bit of the single-device, single-tenant timing, and the
//! multi-tenant driver must be deterministic with real fabric contention.

use axle::config::{Protocol, SimConfig, TopologySpec};
use axle::topo::{DeviceCtx, TenantSpec};
use axle::workload::{by_annotation, ALL_ANNOTATIONS};
use axle::{protocol, topo};

/// All 9 workloads × all 4 protocols: the legacy entry point (fresh
/// internal resources), an explicit fresh [`DeviceCtx`], and a traced
/// ctx must produce byte-identical metrics. The legacy entry point
/// itself constructs resources exactly as the pre-refactor engines did,
/// so this pins the whole matrix to the pre-refactor output.
#[test]
fn single_device_runs_bit_identical_across_ctx_paths() {
    let cfg = SimConfig::m2ndp();
    for a in ALL_ANNOTATIONS {
        let w = by_annotation(a, &cfg);
        for p in Protocol::ALL {
            let legacy = protocol::run(p, &w, &cfg).to_json().to_string();
            let mut ctx = DeviceCtx::new(&cfg);
            let explicit = protocol::run_on(p, &w, &cfg, &mut ctx).to_json().to_string();
            let mut traced = DeviceCtx::traced(&cfg);
            let with_trace = protocol::run_on(p, &w, &cfg, &mut traced).to_json().to_string();
            assert_eq!(legacy, explicit, "workload {a}, {}", p.label());
            assert_eq!(legacy, with_trace, "workload {a}, {} (traced)", p.label());
        }
    }
}

/// Re-running the same protocol on the SAME ctx would accumulate busy
/// state; the topology layer's contract is a fresh ctx per run. Verify
/// a fresh ctx really resets everything (two fresh-ctx runs agree).
#[test]
fn fresh_ctx_per_run_is_stateless() {
    let cfg = SimConfig::m2ndp();
    let w = by_annotation('e', &cfg);
    let first = protocol::run_on(Protocol::Axle, &w, &cfg, &mut DeviceCtx::new(&cfg));
    let second = protocol::run_on(Protocol::Axle, &w, &cfg, &mut DeviceCtx::new(&cfg));
    assert_eq!(first.to_json().to_string(), second.to_json().to_string());
}

/// The PR acceptance scenario, end to end through the public driver:
/// `axle tenants --devices 2 --streams 8` — deterministic per-tenant
/// metrics and nonzero fabric contention on a data-heavy workload.
#[test]
fn tenants_2x8_deterministic_and_contended() {
    let cfg = SimConfig::m2ndp();
    let topo_spec = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
    // Data-heavy mix: graph + DLRM rows move megabytes per iteration.
    let tenants = TenantSpec::new(8).with_workloads(vec!['a', 'd', 'e', 'i']);
    let r1 = topo::run_tenants(&cfg, &topo_spec, &tenants, 8);
    let r2 = topo::run_tenants(&cfg, &topo_spec, &tenants, 2);
    assert_eq!(r1.to_json().to_string(), r2.to_json().to_string(), "worker-count invariance");
    assert_eq!(r1.tenants.len(), 8);
    assert_eq!(r1.devices.len(), 2);
    assert!(r1.devices.iter().all(|d| d.tenants == 4));
    assert!(r1.fabric.wait > 0, "shared fabric must see queueing at 8 streams");
    let heavy_contended = r1
        .tenants
        .iter()
        .any(|t| matches!(t.annot, 'd' | 'e' | 'i') && t.fabric_wait > 0);
    assert!(heavy_contended, "a data-heavy tenant must pay fabric wait");
    // Arrivals are open-loop and strictly ordered.
    for pair in r1.tenants.windows(2) {
        assert!(pair[1].arrival > pair[0].arrival);
    }
    // Every slowdown is ≥ 1 and finite.
    for t in &r1.tenants {
        assert!(t.slowdown() >= 1.0 && t.slowdown().is_finite());
    }
}

/// Tenant solo metrics must equal the solo protocol run — the driver
/// composes the engines, it does not re-model them.
#[test]
fn tenant_solo_pass_is_the_exact_engine() {
    let cfg = SimConfig::m2ndp();
    let topo_spec = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
    let tenants = TenantSpec::new(3)
        .with_workloads(vec!['e'])
        .with_proto(Protocol::Bs);
    let r = topo::run_tenants(&cfg, &topo_spec, &tenants, 4);
    let w = by_annotation('e', &cfg);
    let direct = protocol::run(Protocol::Bs, &w, &cfg);
    for t in &r.tenants {
        assert_eq!(t.solo.to_json().to_string(), direct.to_json().to_string());
    }
}
