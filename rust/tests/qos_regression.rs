//! QoS regression gate (PR 3): the FCFS policy path must stay
//! bit-identical to the PR-2 arbiter, and the new WRR/DRR policies must
//! produce deterministic, seed-stable tenant reports that actually
//! differ from FCFS under contention (the PR acceptance scenario for
//! `axle tenants --qos wrr|drr`).

use axle::config::{QosPolicy, QosSpec, SimConfig, TopologySpec};
use axle::sim::{transfer_ps, BusyTracker, Ps};
use axle::topo::fabric::{arbitrate, arbitrate_qos, FabricMsg};
use axle::topo::{self, TenantSpec};
use axle::util::rng::Pcg32;

/// The PR-2 arbiter, re-implemented verbatim from its published
/// semantics (global `(at, tenant)` order against one wire frontier,
/// max-lateness per tenant). Kept independent of `topo::fabric` so a
/// refactor there cannot silently move the baseline this test pins.
fn pr2_reference(
    mut msgs: Vec<FabricMsg>,
    bw_gbps: f64,
    baseline_bw_gbps: f64,
    n_tenants: usize,
) -> (Vec<Ps>, BusyTracker, u64, u64, Ps) {
    msgs.sort_by_key(|m| (m.at, m.tenant));
    let mut waits: Vec<Ps> = vec![0; n_tenants];
    let mut busy = BusyTracker::new();
    let (mut messages, mut bytes) = (0u64, 0u64);
    let mut wire_free: Ps = 0;
    for m in &msgs {
        let ser = transfer_ps(m.bytes, bw_gbps);
        let solo_finish = m.at + transfer_ps(m.bytes, baseline_bw_gbps);
        let start = m.at.max(wire_free);
        let lateness = (start + ser).saturating_sub(solo_finish);
        let w = &mut waits[m.tenant as usize];
        *w = (*w).max(lateness);
        busy.record(start, start + ser);
        wire_free = start + ser;
        messages += 1;
        bytes += m.bytes;
    }
    (waits, busy, messages, bytes, wire_free)
}

fn random_msgs(rng: &mut Pcg32, n_tenants: usize, count: usize) -> Vec<FabricMsg> {
    let mut t = 0u64;
    (0..count)
        .map(|_| {
            t += rng.below(100_000);
            FabricMsg {
                at: t,
                bytes: rng.range(1, 1 << 18),
                tenant: rng.below(n_tenants as u64) as u32,
            }
        })
        .collect()
}

/// Both the legacy entry point AND the FCFS policy path must reproduce
/// the PR-2 reference field for field on arbitrary inputs, including
/// narrower-than-baseline shared links.
#[test]
fn fcfs_paths_are_bit_identical_to_pr2_reference() {
    let mut rng = Pcg32::seed_from_u64(0x9055_0003);
    for case in 0..40 {
        let n = 1 + (case % 5) as usize;
        let msgs = random_msgs(&mut rng, n, 1 + (case * 7) % 120);
        for (bw, base) in [(16.0, 16.0), (4.0, 16.0), (1.0, 1.0)] {
            let (waits, busy, messages, bytes, wire_free) =
                pr2_reference(msgs.clone(), bw, base, n);
            let legacy = arbitrate(msgs.clone(), bw, base, n);
            let policy = arbitrate_qos(msgs.clone(), bw, base, n, &QosSpec::fcfs());
            for out in [&legacy, &policy] {
                assert_eq!(out.waits, waits, "case {case} bw {bw}");
                assert_eq!(out.busy.union(), busy.union());
                assert_eq!(out.busy.total(), busy.total());
                assert_eq!(out.busy.intervals(), busy.intervals());
                assert_eq!(out.busy.first_start(), busy.first_start());
                assert_eq!(out.messages, messages);
                assert_eq!(out.bytes, bytes);
                assert_eq!(out.wire_free, wire_free);
            }
            assert_eq!(legacy.order, policy.order);
        }
    }
}

/// End to end through the tenant driver: FCFS is the default policy, and
/// an explicitly-FCFS topology is byte-identical to the default across
/// worker counts. (This pins the plumbing, not the arbiter itself — the
/// FCFS-vs-PR-2 bit-identity is pinned at the arbiter level by
/// `fcfs_paths_are_bit_identical_to_pr2_reference` above and by
/// `prop_fcfs_policy_matches_pr2_arbiter` in `proptests.rs`; every
/// tenant-driver wire wait flows through that same `arbitrate_qos`
/// entry point.)
#[test]
fn tenant_driver_defaults_to_fcfs_and_is_invariant() {
    let cfg = SimConfig::m2ndp();
    let topo_spec = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
    assert_eq!(topo_spec.qos.policy, QosPolicy::Fcfs, "FCFS is the default");
    let tenants = TenantSpec::new(8).with_workloads(vec!['a', 'd', 'e', 'i']);
    let explicit_fcfs = topo_spec.clone().with_qos(QosSpec::fcfs());
    let a = topo::run_tenants(&cfg, &topo_spec, &tenants, 4);
    let b = topo::run_tenants(&cfg, &explicit_fcfs, &tenants, 2);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert!(a.fabric.wait > 0, "the pinned scenario must contend");
}

/// The acceptance scenario: `--qos wrr` / `--qos drr` are deterministic,
/// seed-stable, and differ from FCFS under contention.
#[test]
fn wrr_and_drr_tenant_runs_are_seed_stable_and_differ_from_fcfs() {
    let cfg = SimConfig::m2ndp();
    // One device + heavy load ⇒ deep link backlog ⇒ service order matters.
    let topo_spec = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
    let tenants = TenantSpec::new(6).with_workloads(vec!['e', 'i']).with_load(32.0);
    let fcfs = topo::run_tenants(&cfg, &topo_spec, &tenants, 2);
    assert!(fcfs.fabric.wait > 0);
    for qos in [QosSpec::wrr(vec![8, 1]), QosSpec::drr(vec![0.8, 0.1])] {
        let policy = qos.policy;
        let spec = topo_spec.clone().with_qos(qos);
        let r1 = topo::run_tenants(&cfg, &spec, &tenants, 4);
        let r2 = topo::run_tenants(&cfg, &spec, &tenants, 1);
        // Seed-stable: identical reports across repeat runs and worker
        // counts.
        assert_eq!(r1.to_json().to_string(), r2.to_json().to_string(), "{policy:?}");
        assert_eq!(r1.qos, policy);
        // Differs from FCFS under contention.
        let wire = |r: &topo::TenantReport| -> Vec<Ps> {
            r.tenants.iter().map(|t| t.wire_wait()).collect()
        };
        assert_ne!(wire(&fcfs), wire(&r1), "{policy:?} must redistribute waits");
        // But the solo schedules and arrivals are untouched by QoS.
        for (tf, tq) in fcfs.tenants.iter().zip(&r1.tenants) {
            assert_eq!(tf.arrival, tq.arrival);
            assert_eq!(tf.solo.total, tq.solo.total);
            assert_eq!(tf.pu_wait, tq.pu_wait, "PU sharing is policy-independent");
        }
    }
}
