//! End-to-end numerics through the PJRT runtime: every workload's AOT
//! artifacts execute from Rust and match independent Rust references.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent
//! (CI without Python). `make test` always builds artifacts first.

use std::path::PathBuf;

use axle::config::SimConfig;
use axle::runtime::{prand_f32, Runtime};
use axle::workload::ALL_ANNOTATIONS;
use axle::Coordinator;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

#[test]
fn manifest_covers_all_nine_workloads() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let names = rt.names();
    for prefix in [
        "knn_a", "knn_b", "knn_c", "pagerank", "sssp", "ssb_q1", "dlrm",
    ] {
        assert!(
            names.iter().any(|n| n.starts_with(prefix) && n.ends_with("_ccm")),
            "missing {prefix}_ccm"
        );
    }
    assert!(names.contains(&"llm_attn_ccm"));
    assert!(names.contains(&"llm_mlp_host"));
}

#[test]
fn all_workload_numerics_validate() {
    let Some(dir) = artifacts_dir() else { return };
    let mut coord = Coordinator::new(SimConfig::m2ndp()).with_artifacts(dir).unwrap();
    for a in ALL_ANNOTATIONS {
        let r = coord.validate_numerics(a).unwrap_or_else(|e| panic!("({a}): {e:#}"));
        assert!(r.checks > 0, "({a}) no checks ran");
        assert!(r.max_rel_err < 5e-3, "({a}) err {}", r.max_rel_err);
        assert_eq!(r.artifacts.len(), 2, "({a}) must exercise both halves");
    }
}

#[test]
fn executables_are_cached_and_rerunnable() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let q = prand_f32(2048, 7);
    let db = prand_f32(128 * 2048, 8);
    let first = rt.execute_f32("knn_a_ccm", &[&q, &db]).unwrap();
    // Second execution reuses the compiled executable and must agree.
    let second = rt.execute_f32("knn_a_ccm", &[&q, &db]).unwrap();
    assert_eq!(first[0], second[0]);
}

#[test]
fn artifact_outputs_match_manifest_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let entry = rt.entry("ssb_q1_ccm").unwrap().clone();
    let n = entry.inputs[0].shape[0];
    let disc = prand_f32(n, 1);
    let qty = prand_f32(n, 2);
    let out = rt
        .execute_f32("ssb_q1_ccm", &[&disc, &qty, &[0.0, 0.5], &[0.0, 0.5]])
        .unwrap();
    assert_eq!(out.len(), entry.outputs.len());
    assert_eq!(out[0].len(), entry.outputs[0].elements());
    // Marks are boolean-valued.
    assert!(out[0].iter().all(|&m| m == 0.0 || m == 1.0));
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let q = prand_f32(2048, 7);
    assert!(rt.execute_f32("knn_a_ccm", &[&q]).is_err());
    assert!(rt.execute_f32("no_such_artifact", &[&q]).is_err());
}
