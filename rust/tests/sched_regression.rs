//! Scheduler regression gate (PR 4).
//!
//! 1. The open-loop `Static` path of `axle sched` must reproduce the
//!    PR-3 `axle tenants` numbers **bit-identically** (same arrivals,
//!    placement, arbitration and percentiles) — the pin that lets the
//!    closed-loop subsystem ride on top of the tenant driver without
//!    moving any published number.
//! 2. The closed-loop engine must be deterministic and worker-count
//!    invariant on a heterogeneous, fabric-contended scenario.
//! 3. On the acceptance scenario (two tenants alone on two
//!    heterogeneous devices, no shared fabric, zero contention by
//!    construction), `oracle` must lower-bound every policy and
//!    `heuristic` must beat the worst static protocol.

use axle::config::{
    DeviceOverride, FaultEvent, FaultSpec, Placement, PipelineMode, PipelineSpec, PolicyKind,
    Protocol, QosSpec, SchedSpec, SimConfig, TopologySpec, TraceSpec,
};
use axle::sched::{run, SchedReport, SchedRun};
use axle::topo::{run_tenants, TenantSpec};

/// Every test goes through the unified [`run`] entry point; these
/// helpers keep the historical call shape (and double as the migration
/// example for out-of-tree users of the deprecated free functions).
fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
    run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
}

fn run_sched_traced(
    cfg: &SimConfig,
    topo: &TopologySpec,
    spec: &SchedSpec,
    jobs: usize,
) -> (SchedReport, Option<axle::trace::Trace>) {
    let out = run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs));
    (out.report, out.trace)
}

fn data_heavy_mix() -> Vec<char> {
    vec!['a', 'd', 'e', 'i']
}

#[test]
fn open_loop_static_is_bit_identical_to_tenant_path() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![4, 1])] {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_qos(qos);
        let tenant_spec = TenantSpec::new(8)
            .with_workloads(data_heavy_mix())
            .with_proto(Protocol::Axle)
            .with_load(1.0)
            .with_seed(0x7E4A_17);
        let sched_spec = SchedSpec::new(8)
            .with_workloads(data_heavy_mix())
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_load(1.0)
            .with_seed(0x7E4A_17)
            .open_loop();
        let ten = run_tenants(&cfg, &topo, &tenant_spec, 4);
        let sch = run_sched(&cfg, &topo, &sched_spec, 4);

        assert!(!sch.closed);
        assert_eq!(sch.requests.len(), ten.tenants.len());
        for (q, t) in sch.requests.iter().zip(&ten.tenants) {
            assert_eq!(q.tenant, t.tenant);
            assert_eq!(q.annot, t.annot);
            assert_eq!(q.device, t.device);
            assert_eq!(q.submit, t.arrival);
            assert_eq!(q.admit, t.arrival);
            assert_eq!(q.solo, t.solo.total);
            assert_eq!(q.device_wait, t.device_wait);
            assert_eq!(q.fabric_wait, t.fabric_wait);
            assert_eq!(q.pu_wait, t.pu_wait);
            assert_eq!(q.wire_wait(), t.wire_wait());
            assert_eq!(q.total(), t.total());
            assert_eq!(q.completion, t.arrival + t.total());
            assert_eq!(q.slowdown().to_bits(), t.slowdown().to_bits());
        }
        assert_eq!(sch.makespan, ten.makespan);
        assert_eq!(sch.p50_slowdown.to_bits(), ten.p50_slowdown.to_bits());
        assert_eq!(sch.p99_slowdown.to_bits(), ten.p99_slowdown.to_bits());
        assert_eq!(sch.max_slowdown.to_bits(), ten.max_slowdown.to_bits());
        assert_eq!(sch.devices.len(), ten.devices.len());
        for (a, b) in sch.devices.iter().zip(&ten.devices) {
            assert_eq!(a.tenants, b.tenants);
            assert_eq!(a.load, b.load);
            assert_eq!(a.mem_wait, b.mem_wait);
            assert_eq!(a.io_wait, b.io_wait);
            assert_eq!(a.pu_wait, b.pu_wait);
            assert_eq!(a.pu_busy, b.pu_busy);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.link_busy, b.link_busy);
        }
        assert_eq!(sch.fabric.bw_gbps, ten.fabric.bw_gbps);
        assert_eq!(sch.fabric.messages, ten.fabric.messages);
        assert_eq!(sch.fabric.bytes, ten.fabric.bytes);
        assert_eq!(sch.fabric.busy, ten.fabric.busy);
        assert_eq!(sch.fabric.wait, ten.fabric.wait);
        assert_eq!(sch.fabric.utilization.to_bits(), ten.fabric.utilization.to_bits());
    }
}

#[test]
fn open_loop_zero_streams_matches_tenant_empty_report() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
    let sch = run_sched(
        &cfg,
        &topo,
        &SchedSpec::new(0).with_policy(PolicyKind::Static(Protocol::Bs)).open_loop(),
        2,
    );
    assert!(sch.requests.is_empty());
    assert_eq!(sch.makespan, 0);
    assert_eq!(sch.p50_slowdown, 1.0);
    assert_eq!(sch.devices.len(), 2);
}

/// Heterogeneous, fabric-contended closed loop: deterministic and
/// worker-count invariant for every shipped policy.
#[test]
fn closed_loop_deterministic_on_heterogeneous_contended_topology() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    for policy in PolicyKind::ALL {
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['a', 'e'])
            .with_policy(policy)
            .with_requests(2)
            .with_admit(2);
        let a = run_sched(&cfg, &topo, &spec, 1);
        let b = run_sched(&cfg, &topo, &spec, 4);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{}", policy.label());
        assert_eq!(a.requests.len(), 8);
        // Both device classes saw work (round-robin placement).
        assert!(a.devices.iter().all(|d| d.tenants > 0));
    }
}

/// Equal priority classes — whatever their value — must route through
/// the admission queue exactly like the PR-4 FIFO: identical calendars
/// and timings, only the class label moves. This is the bit-identity
/// pin for the priority-admission refactor.
#[test]
fn equal_priority_classes_are_bit_identical_to_fifo() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
    let base = SchedSpec::new(4).with_workloads(vec!['a', 'e']).with_requests(2);
    let plain = run_sched(&cfg, &topo, &base, 2);
    let classed = run_sched(&cfg, &topo, &base.clone().with_priorities(vec![3, 3]), 2);
    assert_eq!(plain.requests.len(), classed.requests.len());
    for (p, c) in plain.requests.iter().zip(&classed.requests) {
        assert_eq!(p.tenant, c.tenant);
        assert_eq!(p.submit, c.submit);
        assert_eq!(p.admit, c.admit);
        assert_eq!(p.completion, c.completion);
        assert_eq!(p.device, c.device);
        assert_eq!(p.proto, c.proto);
        assert_eq!(p.class, 0);
        assert_eq!(c.class, 3);
    }
    assert_eq!(plain.makespan, classed.makespan);
    assert_eq!(plain.p50_slowdown.to_bits(), classed.p50_slowdown.to_bits());
    assert_eq!(plain.p99_slowdown.to_bits(), classed.p99_slowdown.to_bits());
}

/// Online WRR/DRR closed loops are deterministic, worker-count
/// invariant, and conserve wire work versus the FCFS calendars: the
/// same message multiset crosses the same wires (static policy, so the
/// protocol choice cannot drift), so total bytes and busy time match —
/// QoS only redistributes who waits inside them.
#[test]
fn closed_loop_online_qos_deterministic_and_work_conserving() {
    let cfg = SimConfig::m2ndp();
    let mk = |qos: QosSpec| TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_qos(qos);
    let spec = SchedSpec::new(4)
        .with_workloads(data_heavy_mix())
        .with_policy(PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(2)
        .with_priorities(vec![1, 0]);
    let fcfs = run_sched(&cfg, &mk(QosSpec::fcfs()), &spec, 2);
    let bytes = |r: &SchedReport| r.devices.iter().map(|d| d.bytes).sum::<u64>();
    let busy = |r: &SchedReport| r.devices.iter().map(|d| d.link_busy).sum::<u64>();
    for qos in [QosSpec::wrr(vec![4, 1]), QosSpec::drr(vec![0.75, 0.25])] {
        let a = run_sched(&cfg, &mk(qos.clone()), &spec, 1);
        let b = run_sched(&cfg, &mk(qos.clone()), &spec, 4);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{:?}", qos.policy);
        assert_eq!(a.requests.len(), fcfs.requests.len());
        assert_eq!(bytes(&a), bytes(&fcfs), "{:?}", qos.policy);
        assert_eq!(busy(&a), busy(&fcfs), "{:?}", qos.policy);
        assert_eq!(a.fabric.bytes, fcfs.fabric.bytes, "{:?}", qos.policy);
        assert_eq!(a.fabric.busy, fcfs.fabric.busy, "{:?}", qos.policy);
        for q in &a.requests {
            assert_eq!(q.total(), q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait);
        }
    }
}

/// The PR acceptance scenario: one closed-loop tenant (window 1)
/// alternating its requests round-robin across two heterogeneous devices
/// with dedicated uplinks. Window 1 means a request is only submitted
/// after the previous one fully completed, so no two requests ever
/// overlap on any resource — zero contention by construction, and each
/// run is exactly a chain of chosen-protocol solo runtimes. Hence
/// `oracle` (per-request argmin over candidate solos on the target
/// device class) lower-bounds every policy, and the adaptive `heuristic`
/// beats the worst static protocol.
#[test]
fn oracle_bounds_and_heuristic_beats_worst_static_on_hetero_devices() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec { devices: 2, ..TopologySpec::default() }
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let base = SchedSpec::new(1).with_workloads(vec!['e']).with_requests(4).with_depth(1);
    let run = |policy: PolicyKind| run_sched(&cfg, &topo, &base.clone().with_policy(policy), 2);

    let statics: Vec<_> = [Protocol::Rp, Protocol::Bs, Protocol::Axle]
        .iter()
        .map(|&p| run(PolicyKind::Static(p)))
        .collect();
    let heuristic = run(PolicyKind::Heuristic);
    let oracle = run(PolicyKind::Oracle);

    for r in statics.iter().chain([&heuristic, &oracle]) {
        // Zero contention: every wait component is zero in every run,
        // and both device classes served requests (round-robin).
        for q in &r.requests {
            assert_eq!(q.queue_wait(), 0, "{}", r.policy.label());
            assert_eq!(q.wire_wait(), 0, "{}", r.policy.label());
            assert_eq!(q.pu_wait, 0, "{}", r.policy.label());
        }
        assert!(r.devices.iter().all(|d| d.tenants == 2));
    }
    // The weak class (a quarter of the CCM PUs) really is a distinct
    // placement trade-off: under one pinned protocol the same workload's
    // solo runtime is larger there.
    for r in &statics {
        let on_base = r.requests.iter().find(|q| q.device == 0).unwrap();
        let on_weak = r.requests.iter().find(|q| q.device == 1).unwrap();
        assert!(on_weak.solo > on_base.solo, "{}", r.policy.label());
    }

    // Oracle lower-bounds every policy's end-to-end runtime.
    for r in statics.iter().chain(std::iter::once(&heuristic)) {
        assert!(
            oracle.makespan <= r.makespan,
            "oracle {} vs {} {}",
            oracle.makespan,
            r.policy.label(),
            r.makespan
        );
    }
    // Oracle's per-request choice is the argmin over candidate solos on
    // the request's device class.
    for q in &oracle.requests {
        let dev_cfg = topo.device_config(q.device as usize, &cfg);
        let w = axle::workload::by_annotation(q.annot, &dev_cfg);
        let best = [Protocol::Rp, Protocol::Bs, Protocol::Axle]
            .iter()
            .map(|&p| axle::protocol::run(p, &w, &dev_cfg).total)
            .min()
            .unwrap();
        assert_eq!(q.solo, best);
    }

    // The heuristic beats the worst static protocol outright.
    let worst_static = statics.iter().map(|r| r.makespan).max().unwrap();
    assert!(
        heuristic.makespan < worst_static,
        "heuristic {} vs worst static {}",
        heuristic.makespan,
        worst_static
    );
}

/// The fault-layer bit-identity pin (PR 6): a spec whose fault schedule
/// is empty — even with every recovery knob moved off its default —
/// must reproduce the fault-free run **exactly**. The engine never
/// constructs a fault runtime for an empty schedule, so placement,
/// admission, calendars, percentiles (down to the f64 bits) and the
/// serialized JSON all match byte for byte, and none of the sparse
/// fault keys appear.
#[test]
fn empty_fault_spec_is_bit_identical_to_fault_free() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let spec = SchedSpec::new(4)
        .with_workloads(data_heavy_mix())
        .with_requests(2)
        .with_admit(2)
        .with_priorities(vec![1, 0]);
    let knobbed =
        FaultSpec { events: Vec::new(), max_retries: 9, backoff: 123_456, timeout_factor: 2.5 };
    let base = run_sched(&cfg, &topo, &spec, 2);
    let faultless = run_sched(&cfg, &topo, &spec.clone().with_faults(knobbed), 2);

    assert_eq!(base.requests.len(), faultless.requests.len());
    for (a, b) in base.requests.iter().zip(&faultless.requests) {
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.index, b.index);
        assert_eq!(a.device, b.device);
        assert_eq!(a.proto, b.proto);
        assert_eq!(a.submit, b.submit);
        assert_eq!(a.admit, b.admit);
        assert_eq!(a.completion, b.completion);
        assert_eq!(a.solo, b.solo);
        assert_eq!(a.device_wait, b.device_wait);
        assert_eq!(a.fabric_wait, b.fabric_wait);
        assert_eq!(a.pu_wait, b.pu_wait);
        assert_eq!(a.slowdown().to_bits(), b.slowdown().to_bits());
        assert_eq!((b.retries, b.retry_wait, b.failed), (0, 0, false));
        assert_eq!(b.placed_on.len(), 1);
    }
    assert_eq!(base.makespan, faultless.makespan);
    assert_eq!(base.p50_slowdown.to_bits(), faultless.p50_slowdown.to_bits());
    assert_eq!(base.p99_slowdown.to_bits(), faultless.p99_slowdown.to_bits());
    assert_eq!(base.max_slowdown.to_bits(), faultless.max_slowdown.to_bits());
    assert_eq!(base.host_busy, faultless.host_busy);
    assert_eq!(base.ccm_busy, faultless.ccm_busy);
    assert_eq!(base.fabric.busy, faultless.fabric.busy);
    assert_eq!(base.fabric.utilization.to_bits(), faultless.fabric.utilization.to_bits());
    assert!(faultless.faults.is_empty());
    assert_eq!((faultless.lost_wire, faultless.lost_pu, faultless.failed_requests), (0, 0, 0));
    let json = faultless.to_json().to_string();
    assert_eq!(base.to_json().to_string(), json);
    assert!(!json.contains("\"faults\"") && !json.contains("\"retries\""));
}

/// The PR-6 acceptance scenario: a permanent device failure injected
/// mid-service on the strong+weak two-device topology. Under every QoS
/// policy the run must complete on the survivor with zero lost
/// requests, report a positive time-to-recover and the killed attempts'
/// lost work, and stay worker-count invariant.
#[test]
fn mid_run_device_failure_recovers_on_survivor_across_qos_policies() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![4, 1]), QosSpec::drr(vec![0.75, 0.25])] {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
            .with_qos(qos.clone());
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['a', 'e'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(2)
            .with_admit(2);
        // Derive the kill instant from the fault-free baseline: strictly
        // inside a device-0 service window. The engine is deterministic
        // and bit-identical up to the first fault event, so the kill is
        // guaranteed to catch that request in service.
        let base = run_sched(&cfg, &topo, &spec, 2);
        let victim = base
            .requests
            .iter()
            .filter(|q| q.device == 0 && q.completion > q.admit + 1)
            .max_by_key(|q| q.completion - q.admit)
            .expect("device 0 serves work in the baseline");
        let at = victim.admit + (victim.completion - victim.admit) / 2;
        let spec = spec.with_faults(FaultSpec::with(vec![FaultEvent::fail(0, at)]));
        let r = run_sched(&cfg, &topo, &spec, 2);

        // Conservation: nothing lost, nothing hung, nothing dropped.
        assert_eq!(r.requests.len(), base.requests.len(), "{:?}", qos.policy);
        assert_eq!(r.failed_requests, 0, "{:?}", qos.policy);
        for q in &r.requests {
            if q.submit > at {
                assert_eq!(q.device, 1, "post-failure work must land on the survivor");
            }
            assert!(!q.failed);
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait,
                "{:?}",
                qos.policy
            );
        }
        // The fault row reports the displacement, recovery and lost work.
        assert_eq!(r.faults.len(), 1);
        let row = &r.faults[0];
        assert!(row.displaced > 0, "{:?}", qos.policy);
        assert!(row.recover > 0, "{:?}", qos.policy);
        assert!(row.lost_wire + row.lost_pu > 0, "{:?}", qos.policy);
        assert_eq!((r.lost_wire, r.lost_pu), (row.lost_wire, row.lost_pu));
        assert!(r.requests.iter().any(|q| q.placed_on.len() > 1));

        // Faulted runs stay worker-count invariant and deterministic.
        let again = run_sched(&cfg, &topo, &spec, 4);
        assert_eq!(r.to_json().to_string(), again.to_json().to_string(), "{:?}", qos.policy);
    }
}

/// The PR-7 sharding pin: on a fabric-free pinned topology the event
/// engine really shards (devices partitioned across workers, one event
/// heap per shard) — and the merged result must reproduce the
/// single-worker run **byte for byte**, for every policy, with worker
/// counts that divide the device count evenly, unevenly, and exceed it,
/// in both retained and streaming aggregation modes.
#[test]
fn sharded_pinned_runs_match_single_worker_exactly() {
    let cfg = SimConfig::m2ndp();
    let topo =
        TopologySpec { devices: 4, ..TopologySpec::default() }.with_placement(Placement::Pinned);
    for policy in PolicyKind::ALL {
        for retain in [true, false] {
            let spec = SchedSpec::new(8)
                .with_workloads(vec!['a', 'e'])
                .with_policy(policy)
                .with_requests(2)
                .with_admit(2)
                .with_priorities(vec![1, 0])
                .with_retain(retain);
            let one = run_sched(&cfg, &topo, &spec, 1);
            for jobs in [2, 3, 8] {
                let n = run_sched(&cfg, &topo, &spec, jobs);
                assert_eq!(
                    one.to_json().to_string(),
                    n.to_json().to_string(),
                    "{} retain={retain} jobs={jobs}",
                    policy.label()
                );
            }
        }
    }
}

/// Sharding under online per-device QoS arbitration: each device link's
/// WRR/DRR calendar is wholly owned by one shard, so arbitration state
/// never crosses workers and the merge stays exact.
#[test]
fn sharded_pinned_runs_match_single_worker_under_qos() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::wrr(vec![4, 1]), QosSpec::drr(vec![0.75, 0.25])] {
        let topo = TopologySpec { devices: 4, ..TopologySpec::default() }
            .with_placement(Placement::Pinned)
            .with_qos(qos.clone());
        let spec = SchedSpec::new(8)
            .with_workloads(data_heavy_mix())
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(2)
            .with_admit(2)
            .with_priorities(vec![1, 0]);
        let one = run_sched(&cfg, &topo, &spec, 1);
        let four = run_sched(&cfg, &topo, &spec, 4);
        assert_eq!(one.to_json().to_string(), four.to_json().to_string(), "{:?}", qos.policy);
    }
}

/// Streaming aggregation (the CLI default without `--dump-requests`)
/// versus the retained run it replaces: every counter and busy-union
/// aggregate must match exactly — only the slowdown percentiles go
/// through the sketch, and those are bounded by its 2⁻⁸ relative error.
#[test]
fn streaming_aggregates_match_retained_run() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let base = SchedSpec::new(6)
        .with_workloads(data_heavy_mix())
        .with_requests(3)
        .with_admit(2)
        .with_priorities(vec![1, 0]);
    let kept = run_sched(&cfg, &topo, &base, 2);
    let streamed = run_sched(&cfg, &topo, &base.clone().with_retain(false), 2);

    assert!(streamed.streamed && !kept.streamed);
    assert!(streamed.requests.is_empty());
    assert_eq!(streamed.scheduled as usize, kept.requests.len());
    assert_eq!(streamed.makespan, kept.makespan);
    assert_eq!(streamed.host_busy, kept.host_busy);
    assert_eq!(streamed.ccm_busy, kept.ccm_busy);
    assert_eq!(streamed.max_slowdown.to_bits(), kept.max_slowdown.to_bits());
    assert_eq!(streamed.proto_mix, kept.proto_mix);
    let close = |a: f64, b: f64| (a - b).abs() <= b.abs() * 0.01 + 1e-9;
    assert!(close(streamed.p50_slowdown, kept.p50_slowdown));
    assert!(close(streamed.p99_slowdown, kept.p99_slowdown));
    let kc = kept.class_slowdowns();
    let sc = streamed.class_slowdowns();
    assert_eq!(kc.len(), sc.len());
    for ((ca, na, p50a, p99a), (cb, nb, p50b, p99b)) in sc.iter().zip(&kc) {
        assert_eq!((ca, na), (cb, nb));
        assert!(close(*p50a, *p50b), "class {ca} p50 {p50a} vs {p50b}");
        assert!(close(*p99a, *p99b), "class {ca} p99 {p99a} vs {p99b}");
    }
    // Per-device and fabric rows are pure counters: exact either way.
    assert_eq!(streamed.devices.len(), kept.devices.len());
    for (a, b) in streamed.devices.iter().zip(&kept.devices) {
        assert_eq!(a.tenants, b.tenants);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.link_busy, b.link_busy);
        assert_eq!(a.pu_busy, b.pu_busy);
        assert_eq!(a.mem_wait, b.mem_wait);
        assert_eq!(a.io_wait, b.io_wait);
        assert_eq!(a.pu_wait, b.pu_wait);
    }
    assert_eq!(streamed.fabric.bytes, kept.fabric.bytes);
    assert_eq!(streamed.fabric.busy, kept.fabric.busy);
    // The sparse JSON keys appear exactly when streaming.
    assert!(streamed.to_json().to_string().contains("streamed"));
    assert!(!kept.to_json().to_string().contains("streamed"));
}

/// Fault injection under streaming: request slots are recycled, so the
/// attempt-staleness guard must keep kills, retries and recovery
/// accounting identical to the retained run.
#[test]
fn streaming_fault_run_matches_retained_accounting() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let spec = SchedSpec::new(4)
        .with_workloads(vec!['a', 'e'])
        .with_policy(PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(2);
    let base = run_sched(&cfg, &topo, &spec, 2);
    let victim = base
        .requests
        .iter()
        .filter(|q| q.device == 0 && q.completion > q.admit + 1)
        .max_by_key(|q| q.completion - q.admit)
        .expect("device 0 serves work in the baseline");
    let at = victim.admit + (victim.completion - victim.admit) / 2;
    let spec = spec.with_faults(FaultSpec::with(vec![FaultEvent::fail(0, at)]));
    let kept = run_sched(&cfg, &topo, &spec, 2);
    let streamed = run_sched(&cfg, &topo, &spec.clone().with_retain(false), 2);

    assert!(streamed.streamed);
    assert_eq!(streamed.scheduled as usize, kept.requests.len());
    assert_eq!(streamed.makespan, kept.makespan);
    assert_eq!(streamed.failed_requests, kept.failed_requests);
    assert_eq!(streamed.lost_wire, kept.lost_wire);
    assert_eq!(streamed.lost_pu, kept.lost_pu);
    assert_eq!(streamed.faults, kept.faults);
    assert_eq!(streamed.host_busy, kept.host_busy);
    assert_eq!(streamed.ccm_busy, kept.ccm_busy);
}

/// The PR-8 pipelining bit-identity pin: `chunks = 1` — whether the
/// [`PipelineSpec`] is absent, default, or explicitly `chunks = 1` in
/// any mode — must reproduce the whole-request engine **exactly**,
/// field by field down to the f64 bit patterns, across policy × qos ×
/// retention × worker count. The stage-DAG layer is gated off entirely
/// at one chunk, so nothing may move.
#[test]
fn single_chunk_pipeline_is_bit_identical_to_whole_request_engine() {
    let cfg = SimConfig::m2ndp();
    for policy in [PolicyKind::Static(Protocol::Axle), PolicyKind::Heuristic, PolicyKind::Oracle] {
        for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![4, 1]), QosSpec::drr(vec![0.75, 0.25])] {
            let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
                .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
                .with_qos(qos.clone());
            for retain in [true, false] {
                let spec = SchedSpec::new(4)
                    .with_workloads(vec!['a', 'e'])
                    .with_policy(policy)
                    .with_requests(2)
                    .with_admit(2)
                    .with_priorities(vec![1, 0])
                    .with_retain(retain);
                for jobs in [1, 4] {
                    let tag = format!("{} {:?} retain={retain} jobs={jobs}", policy.label(), qos.policy);
                    let plain = run_sched(&cfg, &topo, &spec, jobs);
                    for mode in [PipelineMode::Auto, PipelineMode::Serial, PipelineMode::Pipelined]
                    {
                        let chunked = run_sched(
                            &cfg,
                            &topo,
                            &spec
                                .clone()
                                .with_pipeline(PipelineSpec { chunks: 1, mode }),
                            jobs,
                        );
                        assert_eq!(
                            plain.to_json().to_string(),
                            chunked.to_json().to_string(),
                            "{tag} mode={mode:?}"
                        );
                    }
                }
            }
        }
    }
    // Field-by-field spot check on one retained config, including the
    // f64 bit patterns the JSON round-trip could in principle mask.
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let spec = SchedSpec::new(4)
        .with_workloads(data_heavy_mix())
        .with_requests(2)
        .with_admit(2)
        .with_priorities(vec![1, 0]);
    let plain = run_sched(&cfg, &topo, &spec, 2);
    let pinned =
        run_sched(&cfg, &topo, &spec.clone().with_pipeline(PipelineSpec::default()), 2);
    assert_eq!(plain.requests.len(), pinned.requests.len());
    for (a, b) in plain.requests.iter().zip(&pinned.requests) {
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.index, b.index);
        assert_eq!(a.device, b.device);
        assert_eq!(a.proto, b.proto);
        assert_eq!(a.submit, b.submit);
        assert_eq!(a.admit, b.admit);
        assert_eq!(a.completion, b.completion);
        assert_eq!(a.solo, b.solo);
        assert_eq!(a.device_wait, b.device_wait);
        assert_eq!(a.fabric_wait, b.fabric_wait);
        assert_eq!(a.pu_wait, b.pu_wait);
        assert_eq!(a.slowdown().to_bits(), b.slowdown().to_bits());
    }
    assert_eq!(plain.makespan, pinned.makespan);
    assert_eq!(plain.host_busy, pinned.host_busy);
    assert_eq!(plain.ccm_busy, pinned.ccm_busy);
    assert_eq!(plain.p50_slowdown.to_bits(), pinned.p50_slowdown.to_bits());
    assert_eq!(plain.p99_slowdown.to_bits(), pinned.p99_slowdown.to_bits());
    assert_eq!(plain.max_slowdown.to_bits(), pinned.max_slowdown.to_bits());
    assert_eq!(plain.fabric.busy, pinned.fabric.busy);
    assert_eq!(plain.fabric.utilization.to_bits(), pinned.fabric.utilization.to_bits());
}

/// The PR-8 acceptance direction: on the fig19 strong+weak contended
/// scenario, chunked admission (`--chunks 4`) must *reduce* both the
/// host and CCM idle fractions versus whole-request admission, under
/// FCFS and DRR arbitration alike. One service slot per device with a
/// depth-2 window keeps a successor queued, so every early slot release
/// has work to admit; device busy time is conserved while the makespan
/// shrinks, which is exactly an idle-fraction drop.
#[test]
fn chunked_admission_reduces_host_and_ccm_idle_under_contention() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::fcfs(), QosSpec::drr(vec![0.75, 0.25])] {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
            .with_qos(qos.clone());
        let base = SchedSpec::new(4)
            .with_workloads(vec!['a', 'e', 'i'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(2)
            .with_admit(1)
            .with_depth(2);
        let whole = run_sched(&cfg, &topo, &base, 2);
        let chunked = run_sched(
            &cfg,
            &topo,
            &base.clone().with_pipeline(PipelineSpec::with_chunks(4)),
            2,
        );
        assert_eq!(whole.requests.len(), chunked.requests.len(), "{:?}", qos.policy);
        assert!(
            chunked.makespan < whole.makespan,
            "{:?}: chunked makespan {} !< whole {}",
            qos.policy,
            chunked.makespan,
            whole.makespan
        );
        assert!(
            chunked.host_idle_frac() < whole.host_idle_frac(),
            "{:?}: chunked host idle {} !< whole {}",
            qos.policy,
            chunked.host_idle_frac(),
            whole.host_idle_frac()
        );
        assert!(
            chunked.ccm_idle_frac() < whole.ccm_idle_frac(),
            "{:?}: chunked ccm idle {} !< whole {}",
            qos.policy,
            chunked.ccm_idle_frac(),
            whole.ccm_idle_frac()
        );
        // The five-way decomposition stays an identity per request at
        // stage granularity, and chunking is deterministic and
        // worker-count invariant like every other engine path.
        for q in &chunked.requests {
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait,
                "{:?}",
                qos.policy
            );
        }
        let again = run_sched(
            &cfg,
            &topo,
            &base.clone().with_pipeline(PipelineSpec::with_chunks(4)),
            4,
        );
        assert_eq!(chunked.to_json().to_string(), again.to_json().to_string(), "{:?}", qos.policy);
    }
}

/// Chunk-granular fault accounting: a mid-service kill of a partially
/// back-streamed chunked request forfeits only its incomplete chunks —
/// strictly less lost work than the same kill under whole-request
/// admission, and never zero (the kill lands mid-attempt). The scenario
/// is zero-contention by construction (one tenant, window 1), where
/// chunked placement provably reproduces the whole-request timeline —
/// so the kill instant derived from the whole-request baseline lands
/// inside the *same* service window in both runs and only the loss
/// accounting can differ.
#[test]
fn mid_service_kill_of_chunked_request_loses_only_incomplete_chunks() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec { devices: 2, ..TopologySpec::default() };
    let spec = SchedSpec::new(1)
        .with_workloads(vec!['e'])
        .with_policy(PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_depth(1);
    let chunked_spec = spec.clone().with_pipeline(PipelineSpec::with_chunks(8));
    let base = run_sched(&cfg, &topo, &spec, 2);
    let victim = base
        .requests
        .iter()
        .filter(|q| q.device == 0 && q.completion > q.admit + 4)
        .max_by_key(|q| q.completion - q.admit)
        .expect("device 0 serves work in the baseline");
    let at = victim.admit + (victim.completion - victim.admit) / 2;
    let faults = FaultSpec::with(vec![FaultEvent::fail(0, at)]);

    let whole = run_sched(&cfg, &topo, &spec.clone().with_faults(faults.clone()), 2);
    let chunked = run_sched(&cfg, &topo, &chunked_spec.clone().with_faults(faults), 2);

    // No request is ever lost: the run completes on the survivor.
    for r in [&whole, &chunked] {
        assert_eq!(r.failed_requests, 0);
        assert_eq!(r.requests.len(), base.requests.len());
        assert!(r.faults[0].displaced > 0);
        assert!(r.requests.iter().all(|q| !q.failed));
    }
    // Whole-request accounting forfeits the entire attempt; chunked
    // accounting banks every chunk whose completion bound precedes the
    // kill, so its lost work is strictly smaller but still positive.
    assert!(chunked.lost_wire + chunked.lost_pu > 0, "kill lands mid-attempt");
    assert!(
        chunked.lost_wire + chunked.lost_pu < whole.lost_wire + whole.lost_pu,
        "chunked lost {}+{} !< whole lost {}+{}",
        chunked.lost_wire,
        chunked.lost_pu,
        whole.lost_wire,
        whole.lost_pu
    );
}

/// No request is ever lost at chunk granularity: random-ish but
/// deterministic fault schedules (stalls, degradations and a permanent
/// failure) over a chunked closed loop must complete every request
/// within the retry budget, keep the five-way decomposition an
/// identity, and report non-negative bounded lost work.
#[test]
fn chunked_runs_survive_mixed_fault_schedules_without_losing_requests() {
    let cfg = SimConfig::m2ndp();
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let us = axle::sim::US;
    for chunks in [2, 4, 8] {
        let faults = FaultSpec::with(vec![
            FaultEvent::stall(0, 3 * us, 9 * us),
            FaultEvent::degrade_pus(1, 2 * us, 20 * us, 3.0),
            FaultEvent::degrade_link(0, 12 * us, 30 * us, 2.0),
            FaultEvent::fail(1, 40 * us),
        ]);
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['a', 'e'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(3)
            .with_admit(2)
            .with_pipeline(PipelineSpec::with_chunks(chunks))
            .with_faults(faults);
        let r = run_sched(&cfg, &topo, &spec, 2);
        assert_eq!(r.requests.len(), 4 * 3, "chunks={chunks}");
        assert_eq!(r.failed_requests, 0, "chunks={chunks}");
        for q in &r.requests {
            assert!(!q.failed, "chunks={chunks}");
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait,
                "chunks={chunks}"
            );
        }
        // Deterministic across worker counts, like every engine path.
        let again = run_sched(&cfg, &topo, &spec, 4);
        assert_eq!(r.to_json().to_string(), again.to_json().to_string(), "chunks={chunks}");
    }
}

/// Tracing is observation-only: with `spec.trace` set, the returned
/// `SchedReport` must be **byte-identical** (its JSON dump, which
/// carries every f64 through `Json::Num`) to the untraced run of the
/// same spec, across scheduling policy × link arbitration × chunked
/// admission × worker count. Each recorded trace must also reconcile
/// exactly with its own report (`trace::validate`).
#[test]
fn tracing_is_observation_only_across_policy_qos_chunks_jobs() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![4, 1]), QosSpec::drr(vec![0.75, 0.25])] {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
            .with_qos(qos.clone());
        for chunks in [1, 4] {
            let spec = SchedSpec::new(4)
                .with_workloads(vec!['a', 'e', 'i'])
                .with_policy(PolicyKind::Static(Protocol::Axle))
                .with_requests(2)
                .with_admit(1)
                .with_depth(2)
                .with_priorities(vec![1, 0])
                .with_pipeline(PipelineSpec::with_chunks(chunks));
            for jobs in [1, 2] {
                let plain = run_sched(&cfg, &topo, &spec, jobs);
                let (traced, tr) = run_sched_traced(
                    &cfg,
                    &topo,
                    &spec.clone().with_trace(TraceSpec::default()),
                    jobs,
                );
                let tag = format!("{:?} chunks={chunks} jobs={jobs}", qos.policy);
                assert_eq!(
                    plain.to_json().to_string(),
                    traced.to_json().to_string(),
                    "trace flipped a result bit: {tag}"
                );
                let tr = tr.expect("trace spec is set");
                assert!(!tr.is_empty(), "{tag}");
                axle::trace::validate(&tr, &traced)
                    .unwrap_or_else(|e| panic!("trace does not reconcile ({tag}): {e}"));
            }
        }
    }
}

/// Shard trace merge: on a shardable topology (Pinned placement, no
/// fabric, no faults) the per-shard event buffers are disjoint
/// multisets whose canonically-sorted union must equal the `--jobs 1`
/// recording byte-for-byte — pinned on the exported Chrome JSON, the
/// strictest serialization of the trace.
#[test]
fn merged_shard_trace_matches_single_worker_trace() {
    let cfg = SimConfig::m2ndp();
    let topo =
        TopologySpec { devices: 4, ..TopologySpec::default() }.with_placement(Placement::Pinned);
    let spec = SchedSpec::new(8)
        .with_workloads(data_heavy_mix())
        .with_policy(PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(2)
        .with_trace(TraceSpec::default());
    let (r1, t1) = run_sched_traced(&cfg, &topo, &spec, 1);
    let t1 = t1.expect("trace spec is set");
    axle::trace::validate(&t1, &r1).expect("single-worker trace reconciles");
    for jobs in [2, 4] {
        let (rn, tn) = run_sched_traced(&cfg, &topo, &spec, jobs);
        let tn = tn.expect("trace spec is set");
        assert_eq!(r1.to_json().to_string(), rn.to_json().to_string(), "jobs={jobs}");
        assert_eq!(
            axle::trace::chrome::to_json(&t1).to_string(),
            axle::trace::chrome::to_json(&tn).to_string(),
            "merged shard trace diverged from --jobs 1 at jobs={jobs}"
        );
    }
}

/// Fault runs under the tracer: a mid-run device kill exercises the
/// Failed / Retry / Requeue / FaultBegin / FaultEnd events and the
/// tracer's calendar-truncation mirror. The report must stay
/// bit-identical to the untraced faulted run and the trace must still
/// reconcile (lost-work accounting included).
#[test]
fn traced_fault_run_is_bit_identical_and_validates() {
    let cfg = SimConfig::m2ndp();
    let us = axle::sim::US;
    let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
    let faults = FaultSpec::with(vec![
        FaultEvent::stall(1, 2 * us, 8 * us),
        FaultEvent::fail(0, 10 * us),
    ]);
    for chunks in [1, 4] {
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['a', 'e'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(3)
            .with_admit(2)
            .with_pipeline(PipelineSpec::with_chunks(chunks))
            .with_faults(faults.clone());
        let plain = run_sched(&cfg, &topo, &spec, 2);
        let (traced, tr) =
            run_sched_traced(&cfg, &topo, &spec.clone().with_trace(TraceSpec::default()), 2);
        assert_eq!(
            plain.to_json().to_string(),
            traced.to_json().to_string(),
            "chunks={chunks}"
        );
        let tr = tr.expect("trace spec is set");
        axle::trace::validate(&tr, &traced)
            .unwrap_or_else(|e| panic!("faulted trace does not reconcile (chunks={chunks}): {e}"));
    }
}

/// PR 10 acceptance: on the nonstationary scenario (two *identical*
/// devices behind a shared fabric, least-loaded placement, an 8x
/// PU-and-link degradation landing on device 0 a quarter of the way
/// into the fault-free heuristic makespan and outlasting every run)
/// the learned decider must re-converge onto the healthy device, while
/// `heuristic` and `oracle` — whose least-loaded placement weighs
/// *undegraded* solo-latency load estimates — keep splitting work onto
/// the slow device for the rest of the run.
#[test]
fn learned_reconverges_under_nonstationary_degradation() {
    let coord = axle::coordinator::Coordinator::new(SimConfig::m2ndp());
    let out = coord.run_nonstationary_scenario(6, 6, 2);
    for (name, r) in
        [("learned", &out.learned), ("heuristic", &out.heuristic), ("oracle", &out.oracle)]
    {
        assert_eq!(r.scheduled, 36, "{name} lost requests");
        assert_eq!(r.failed_requests, 0, "{name} dropped requests");
        assert_eq!(r.requests.len(), 36, "{name} retained rows");
    }
    assert!(out.at > 0 && out.until > out.at, "degradation window is degenerate");
    // The tentpole claim, stated the way the issue asks for it:
    // strictly better than the stale-profile heuristic, and within a
    // 25% bound of oracle (oracle shares the heuristic's static
    // placement here, so learned normally beats it outright — the
    // bound only leaves room for exploration overhead).
    assert!(
        out.learned.makespan < out.heuristic.makespan,
        "learned makespan {} is not strictly below heuristic {}",
        out.learned.makespan,
        out.heuristic.makespan
    );
    assert!(
        out.learned.makespan <= out.oracle.makespan.saturating_mul(5) / 4,
        "learned makespan {} is outside the 5/4 oracle bound ({})",
        out.learned.makespan,
        out.oracle.makespan
    );
    // Faulted runs always collapse to one shard, so worker count can
    // never bend the outcome — pin it anyway, byte-for-byte.
    let again = coord.run_nonstationary_scenario(6, 6, 4);
    for (name, a, b) in [
        ("learned", &out.learned, &again.learned),
        ("heuristic", &out.heuristic, &again.heuristic),
        ("oracle", &out.oracle, &again.oracle),
    ] {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{name} drifted across worker counts"
        );
    }
}

/// The deprecated free functions are thin shims over [`run`]: their
/// reports must stay byte-identical to the options-struct entry point
/// across policy (including the stateful learned decider) × QoS ×
/// chunked admission × worker count for the deprecation window.
#[test]
#[allow(deprecated)]
fn deprecated_wrappers_match_unified_run() {
    let cfg = SimConfig::m2ndp();
    for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![3, 1])] {
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() })
            .with_qos(qos.clone());
        for policy in PolicyKind::ALL {
            for chunks in [1, 4] {
                let spec = SchedSpec::new(4)
                    .with_workloads(vec!['a', 'e'])
                    .with_policy(policy)
                    .with_requests(2)
                    .with_admit(2)
                    .with_pipeline(PipelineSpec::with_chunks(chunks));
                let tag = format!("{policy:?} {:?} chunks={chunks}", qos.policy);
                let unified = run(&SchedRun::new(&cfg, &topo, &spec)).report;
                for jobs in [1, 2] {
                    let legacy = axle::sched::run_sched(&cfg, &topo, &spec, jobs);
                    assert_eq!(
                        unified.to_json().to_string(),
                        legacy.to_json().to_string(),
                        "run_sched diverged from run(): {tag} jobs={jobs}"
                    );
                }
                let tspec = spec.clone().with_trace(TraceSpec::default());
                let traced = run(&SchedRun::new(&cfg, &topo, &tspec)).report;
                let (legacy, tr) = axle::sched::run_sched_traced(&cfg, &topo, &tspec, 1);
                assert_eq!(
                    traced.to_json().to_string(),
                    legacy.to_json().to_string(),
                    "run_sched_traced diverged from run(): {tag}"
                );
                assert!(tr.is_some(), "wrapper dropped the trace: {tag}");
            }
        }
    }
}
