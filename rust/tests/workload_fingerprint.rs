//! Workload-fingerprint guard: closes the "sweep cache could serve stale
//! specs" ROADMAP hazard.
//!
//! The sweep engine's spec cache keys on exactly the config fields
//! workload generation reads (`host`, `ccm`, `cxl_bw_gbps` — mirrored by
//! `SimConfig::workload_fingerprint`). Two invariants keep that safe:
//!
//! 1. **Completeness** — perturbing any field *outside* the fingerprint
//!    must leave every generated `WorkloadSpec` bit-identical. If this
//!    ever fails, a generator started reading a new config field and the
//!    fingerprint (plus `sweep::cache::WorkloadKey`) must fold it in.
//! 2. **Sensitivity** — perturbing a fingerprinted field must change the
//!    fingerprint (the cache rebuilds; conservative over-rebuilding for
//!    fields like `uthreads` that no generator reads today is fine), and
//!    for the structure-determining knobs the specs themselves must
//!    actually differ.

use axle::config::{SchedPolicy, SfPolicy, SimConfig};
use axle::workload::{by_annotation, WorkloadSpec};

/// One workload per generator function: KNN, SSSP, PageRank, two SSB
/// queries, LLM attention, DLRM. (b/c share 'a's generator.)
const GUARD_ANNOTS: [char; 7] = ['a', 'd', 'e', 'f', 'g', 'h', 'i'];

fn specs(cfg: &SimConfig) -> Vec<WorkloadSpec> {
    GUARD_ANNOTS.iter().map(|&a| by_annotation(a, cfg)).collect()
}

/// Every non-fingerprinted (simulation-time) knob, perturbed one at a
/// time.
fn non_fingerprinted_perturbations() -> Vec<(&'static str, SimConfig)> {
    let base = SimConfig::m2ndp();
    let mut out: Vec<(&'static str, SimConfig)> = Vec::new();
    let mut push = |name: &'static str, f: &dyn Fn(&mut SimConfig)| {
        let mut c = base.clone();
        f(&mut c);
        out.push((name, c));
    };
    push("cxl_mem_rtt", &|c| c.cxl_mem_rtt *= 2);
    push("cxl_io_rtt", &|c| c.cxl_io_rtt *= 2);
    push("firmware_freq_ghz", &|c| c.firmware_freq_ghz *= 2.0);
    push("rp_poll_interval", &|c| c.rp_poll_interval *= 2);
    push("sched", &|c| c.sched = SchedPolicy::Fifo);
    push("axle.poll_interval", &|c| c.axle.poll_interval *= 2);
    push("axle.streaming_factor_bytes", &|c| c.axle.streaming_factor_bytes *= 2);
    push("axle.sf_policy", &|c| c.axle.sf_policy = SfPolicy::Adaptive);
    push("axle.dma_slot_bytes", &|c| c.axle.dma_slot_bytes *= 2);
    push("axle.dma_slot_capacity", &|c| c.axle.dma_slot_capacity /= 2);
    push("axle.dma_prep", &|c| c.axle.dma_prep *= 2);
    push("axle.interrupt_latency", &|c| c.axle.interrupt_latency *= 2);
    push("axle.ooo_streaming", &|c| c.axle.ooo_streaming = false);
    push("seed", &|c| c.seed ^= 0xBEEF);
    push("jitter", &|c| c.jitter += 0.05);
    out
}

/// Every fingerprinted (generation-relevant) knob, perturbed one at a
/// time, with whether the perturbation must visibly change the specs.
fn fingerprinted_perturbations() -> Vec<(&'static str, SimConfig, bool)> {
    let base = SimConfig::m2ndp();
    let mut out: Vec<(&'static str, SimConfig, bool)> = Vec::new();
    let mut push = |name: &'static str, must_change_specs: bool, f: &dyn Fn(&mut SimConfig)| {
        let mut c = base.clone();
        f(&mut c);
        out.push((name, c, must_change_specs));
    };
    // Structure-determining: task partitioning / durations shift.
    push("ccm.num_pus", true, &|c| c.ccm.num_pus /= 2);
    push("host.freq_ghz", true, &|c| c.host.freq_ghz /= 2.0);
    push("ccm.freq_ghz", false, &|c| c.ccm.freq_ghz /= 2.0);
    push("ccm.flops_per_cycle", false, &|c| c.ccm.flops_per_cycle /= 2.0);
    push("ccm.dram_channels", false, &|c| c.ccm.dram_channels /= 2);
    push("host.num_pus", false, &|c| c.host.num_pus /= 2);
    push("host.uthreads", false, &|c| c.host.uthreads += 1);
    push("host.flops_per_cycle", false, &|c| c.host.flops_per_cycle *= 2.0);
    push("host.dram_channels", false, &|c| c.host.dram_channels /= 2);
    push("ccm.uthreads", false, &|c| c.ccm.uthreads += 1);
    push("cxl_bw_gbps", false, &|c| c.cxl_bw_gbps /= 2.0);
    out
}

#[test]
fn non_fingerprinted_fields_never_change_generated_specs() {
    let base = SimConfig::m2ndp();
    let baseline = specs(&base);
    for (name, cfg) in non_fingerprinted_perturbations() {
        assert_eq!(
            cfg.workload_fingerprint(),
            base.workload_fingerprint(),
            "perturbing {name} must not move the workload fingerprint"
        );
        let got = specs(&cfg);
        for (w, b) in got.iter().zip(&baseline) {
            assert_eq!(
                w,
                b,
                "perturbing {name} changed generated spec ({}): a generator \
                 reads this field — fold it into SimConfig::workload_fingerprint \
                 and sweep::cache::WorkloadKey or the sweep cache serves stale specs",
                b.annot
            );
        }
    }
}

#[test]
fn fingerprinted_fields_always_move_the_fingerprint() {
    let base = SimConfig::m2ndp();
    let baseline = specs(&base);
    for (name, cfg, must_change_specs) in fingerprinted_perturbations() {
        assert_ne!(
            cfg.workload_fingerprint(),
            base.workload_fingerprint(),
            "perturbing {name} must move the workload fingerprint (cache key)"
        );
        assert_ne!(cfg.fingerprint(), base.fingerprint(), "full fingerprint for {name}");
        if must_change_specs {
            let got = specs(&cfg);
            assert!(
                got.iter().zip(&baseline).any(|(w, b)| w != b),
                "perturbing {name} should visibly change at least one generated spec"
            );
        }
    }
}

#[test]
fn cache_key_and_fingerprint_agree_on_every_perturbation() {
    // The exact-tuple cache key (WorkloadCache) and the lossy fingerprint
    // must partition configs the same way for every perturbation above:
    // same fingerprint ⇒ cache reuses the spec ⇒ specs must be equal.
    let base = SimConfig::m2ndp();
    let mut cache = axle::sweep::WorkloadCache::new();
    let a0 = cache.get('a', &base);
    for (name, cfg) in non_fingerprinted_perturbations() {
        let a1 = cache.get('a', &cfg);
        assert!(
            std::sync::Arc::ptr_eq(&a0, &a1),
            "cache must share specs across the {name} perturbation"
        );
    }
    for (name, cfg, _) in fingerprinted_perturbations() {
        let a1 = cache.get('a', &cfg);
        assert!(
            !std::sync::Arc::ptr_eq(&a0, &a1),
            "cache must rebuild specs across the {name} perturbation"
        );
    }
}
