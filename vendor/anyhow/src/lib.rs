//! Offline, dependency-free stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so the crate's `anyhow`
//! usage is satisfied by this minimal source-compatible shim: a
//! string-backed [`Error`], the [`anyhow!`]/[`bail!`] macros, the
//! [`Context`] extension trait, and the [`Result`] alias. When network
//! access is available, point `rust/Cargo.toml` at the real crate — no
//! call site changes are needed for the subset used here.

use std::fmt;

/// String-backed error with context chaining (shim for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context layer (`context: original`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real `anyhow::Error`, this type deliberately does NOT
// implement `std::error::Error` — that is what keeps this blanket
// conversion coherent with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result` alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_messages() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn option_context_and_with_context() {
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let n: Option<u32> = None;
        assert_eq!(n.with_context(|| format!("missing {}", 7)).unwrap_err().to_string(), "missing 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn std_errors_convert() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xFF])?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero input {x}");
            }
            Ok(x)
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero input 0");
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(anyhow!("v={}", 3).to_string(), "v=3");
        assert_eq!(anyhow!("plain").to_string(), "plain");
    }
}
