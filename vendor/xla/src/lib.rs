//! Offline API stub for the `xla` (PJRT) crate — see README.md.
//!
//! Mirrors the subset of the real crate's API that `axle`'s runtime
//! module uses. Constructors that only shuffle metadata succeed; every
//! entry point that would require the PJRT runtime returns an error, so
//! the workspace builds and tests offline while `axle validate` fails
//! with a clear message instead of a link error.

use std::fmt;

/// Error type standing in for the real crate's `xla::Error`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: the vendored `xla` stub has no PJRT backend (offline build); \
         swap vendor/xla for the real crate to execute artifacts"
    ))
}

/// Element types the runtime converts between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Native element types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor value (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(unavailable("Literal::convert"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client (stub: construction reports the missing backend).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
